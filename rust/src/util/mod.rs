//! Small in-tree substrates: deterministic PRNG, property-test harness,
//! scoped thread helpers, and a bench timer.
//!
//! The build environment is offline, so the usual crates (`rand`,
//! `proptest`, `criterion`, `tokio`) are unavailable; these utilities
//! provide the subset the system needs, built from scratch.

pub mod alloc_count;
pub mod bits;
pub mod fxhash;
mod rng;

pub use rng::Rng;

/// Run a property over `cases` deterministic seeds; panics with the
/// failing seed on the first violation (an in-tree stand-in for
/// proptest's runner — rerun with the printed seed to reproduce).
pub fn property<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64
            .wrapping_mul(case + 1)
            .wrapping_add(0xD1B54A32D192ED03);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Median-of-runs wall-clock timer for the report benches.
///
/// Runs `f` once for warm-up, then `runs` times, returning the median
/// duration. Deterministic workloads only (no randomness inside `f`).
pub fn time_median<T, F: FnMut() -> T>(runs: usize, mut f: F) -> (std::time::Duration, T) {
    let mut out = f(); // warm-up
    let mut samples = Vec::with_capacity(runs.max(1));
    for _ in 0..runs.max(1) {
        let t0 = std::time::Instant::now();
        out = f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    (samples[samples.len() / 2], out)
}

/// Time a single invocation of `f` on the monotonic clock
/// (`Instant`). The perf suite times each pipeline phase separately
/// with this and medians the per-phase samples across repeats.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (std::time::Duration, T) {
    let t0 = std::time::Instant::now();
    let out = f();
    (t0.elapsed(), out)
}

/// Median of duration samples (sorts in place; empty slice -> zero).
pub fn median_duration(samples: &mut [std::time::Duration]) -> std::time::Duration {
    if samples.is_empty() {
        return std::time::Duration::ZERO;
    }
    samples.sort_unstable();
    samples[samples.len() / 2]
}

/// Run jobs on a scoped thread pool, preserving order (std-only
/// replacement for the tokio blocking pool on this single-core box).
pub fn parallel_map<T, U, F>(items: Vec<T>, threads: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = std::sync::Mutex::new(work);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let slots = std::sync::Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                let Some((i, t)) = item else { break };
                let u = f(t);
                slots.lock().unwrap()[i] = Some(u);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker dropped a job")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut n = 0;
        property("count", 10, |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic]
    fn property_propagates_failure() {
        property("fail", 5, |rng| {
            assert!(rng.range_i64(0, 10) < 100); // always true
            panic!("boom");
        });
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..37).collect();
        let got = parallel_map(items.clone(), 4, |x| x * x);
        let want: Vec<u64> = items.iter().map(|x| x * x).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn time_median_returns_value() {
        let (d, v) = time_median(3, || 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() < 1_000_000);
    }

    #[test]
    fn time_once_returns_value_and_duration() {
        let (d, v) = time_once(|| "ok");
        assert_eq!(v, "ok");
        assert!(d.as_secs() < 1);
    }

    #[test]
    fn median_duration_examples() {
        use std::time::Duration;
        assert_eq!(median_duration(&mut []), Duration::ZERO);
        let mut one = [Duration::from_millis(7)];
        assert_eq!(median_duration(&mut one), Duration::from_millis(7));
        let mut three = [
            Duration::from_millis(9),
            Duration::from_millis(1),
            Duration::from_millis(5),
        ];
        assert_eq!(median_duration(&mut three), Duration::from_millis(5));
    }
}
