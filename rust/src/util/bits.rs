//! Word-parallel bitsets for the optimizer hot path.
//!
//! The CSE engine tracks two kinds of occupancy: which digit slots of a
//! column are alive, and which columns a pattern currently occurs in.
//! Both were `bool` flags / `BTreeMap` keys before the allocation pass;
//! a flat `Vec<u64>` bitset gives the same ascending-order iteration
//! with word-parallel skips over empty regions and no per-entry heap
//! churn. The backing words are recyclable: `take_words`/`from_words`
//! let an arena pool zeroed word vectors across compiles.

/// Growable bitset over `u32` indices backed by `Vec<u64>` words.
#[derive(Debug, Default, Clone)]
pub struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    /// New empty bitset (no backing storage until the first `set`).
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from a recycled word vector. The caller must pass
    /// all-zero words (the arena pools zeroed vectors).
    pub fn from_words(words: Vec<u64>) -> Self {
        debug_assert!(words.iter().all(|&w| w == 0), "pooled words must be zeroed");
        Self { words }
    }

    /// Surrender the backing words for pooling. NOT zeroed — the caller
    /// zeroes before re-pooling (`fill(0)` is a word-parallel memset).
    pub fn take_words(self) -> Vec<u64> {
        self.words
    }

    /// Set bit `i`, growing the word vector as needed.
    pub fn set(&mut self, i: u32) {
        let word = (i / 64) as usize;
        if word >= self.words.len() {
            self.words.resize(word + 1, 0);
        }
        self.words[word] |= 1u64 << (i % 64);
    }

    /// Clear bit `i` (no-op when out of range).
    pub fn unset(&mut self, i: u32) {
        let word = (i / 64) as usize;
        if word < self.words.len() {
            self.words[word] &= !(1u64 << (i % 64));
        }
    }

    /// Test bit `i`.
    pub fn get(&self, i: u32) -> bool {
        let word = (i / 64) as usize;
        word < self.words.len() && self.words[word] & (1u64 << (i % 64)) != 0
    }

    /// Clear all bits, keeping capacity.
    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// True when no bit is set.
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Number of set bits.
    pub fn count(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Iterate set bits in ascending order.
    pub fn iter(&self) -> Bits<'_> {
        Bits { words: &self.words, word_idx: 0, cur: 0 }
    }
}

/// Ascending iterator over set bits; skips empty words whole.
pub struct Bits<'a> {
    words: &'a [u64],
    word_idx: usize,
    cur: u64,
}

impl Iterator for Bits<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        while self.cur == 0 {
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.word_idx];
            self.word_idx += 1;
        }
        let t = self.cur.trailing_zeros();
        self.cur &= self.cur - 1;
        Some((self.word_idx as u32 - 1) * 64 + t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_unset_roundtrip() {
        let mut b = BitSet::new();
        assert!(b.is_empty());
        for i in [0u32, 1, 63, 64, 65, 127, 128, 1000] {
            assert!(!b.get(i));
            b.set(i);
            assert!(b.get(i));
        }
        assert_eq!(b.count(), 8);
        b.unset(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 7);
        // unset beyond capacity is a no-op
        b.unset(100_000);
    }

    #[test]
    fn iter_is_ascending_and_complete() {
        let mut b = BitSet::new();
        let bits = [5u32, 0, 200, 64, 63, 129];
        for &i in &bits {
            b.set(i);
        }
        let got: Vec<u32> = b.iter().collect();
        let mut want = bits.to_vec();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn clear_keeps_capacity_and_pooling_roundtrip() {
        let mut b = BitSet::new();
        b.set(300);
        b.clear();
        assert!(b.is_empty());
        let mut words = b.take_words();
        assert!(!words.is_empty());
        words.fill(0);
        let mut b2 = BitSet::from_words(words);
        assert!(b2.is_empty());
        b2.set(3);
        assert_eq!(b2.iter().collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn iter_matches_btreeset_on_random_bits() {
        crate::util::property("bits vs btreeset", 32, |rng| {
            let mut b = BitSet::new();
            let mut model = std::collections::BTreeSet::new();
            for _ in 0..100 {
                let i = rng.range_i64(0, 500) as u32;
                if rng.range_i64(0, 4) == 0 {
                    b.unset(i);
                    model.remove(&i);
                } else {
                    b.set(i);
                    model.insert(i);
                }
            }
            let got: Vec<u32> = b.iter().collect();
            let want: Vec<u32> = model.iter().copied().collect();
            assert_eq!(got, want);
            assert_eq!(b.count() as usize, model.len());
        });
    }
}
