//! Deterministic SplitMix64 + xoshiro256** PRNG (in-tree `rand`
//! replacement; the offline build environment has no `rand` crate).
//!
//! Statistical quality is ample for workload generation; determinism
//! across platforms is guaranteed (pure integer arithmetic).

/// A seedable PRNG (xoshiro256**, seeded through SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[lo, hi]` inclusive (unbiased via rejection).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "empty range [{lo}, {hi}]");
        let span = (hi as i128 - lo as i128 + 1) as u128;
        if span == 1 {
            return lo;
        }
        if span == 1u128 << 64 {
            // Full i64 range (lo == i64::MIN, hi == i64::MAX): every u64
            // bit pattern maps to a distinct in-range value, and the
            // truncation `span as u64 == 0` below would divide by zero.
            return self.next_u64() as i64;
        }
        // Rejection sampling on the top multiple of span.
        let span64 = span as u64; // span < 2^64 here
        let zone = u64::MAX - (u64::MAX % span64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (lo as i128 + (v % span64) as i128) as i64;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        self.range_i64(0, n as i64 - 1) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut rng = Rng::seed_from(7);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = rng.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn range_roughly_uniform() {
        let mut rng = Rng::seed_from(1);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[rng.below(8)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(3);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Rng::seed_from(9);
        let mut v: Vec<u32> = (0..20).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<u32>>());
        assert_ne!(v, (0..20).collect::<Vec<u32>>());
    }

    #[test]
    fn single_point_range() {
        let mut rng = Rng::seed_from(5);
        assert_eq!(rng.range_i64(4, 4), 4);
    }

    /// Regression: the full-span and near-full-span ranges used to hit a
    /// `u64::MAX % 0` division-by-zero (`span as u64 == 0` truncation).
    #[test]
    fn prop_extreme_ranges_never_panic() {
        crate::util::property("rng_extreme_ranges", 16, |rng| {
            // Full span: any i64 is valid; must not panic.
            let _ = rng.range_i64(i64::MIN, i64::MAX);
            // Near-full spans exercise the rejection path at span ~ 2^64.
            let v = rng.range_i64(i64::MIN + 1, i64::MAX);
            assert!(v >= i64::MIN + 1);
            let w = rng.range_i64(i64::MIN, i64::MAX - 1);
            assert!(w <= i64::MAX - 1);
            // Extreme single-sided bounds.
            assert_eq!(rng.range_i64(i64::MAX, i64::MAX), i64::MAX);
            assert_eq!(rng.range_i64(i64::MIN, i64::MIN), i64::MIN);
        });
    }

    #[test]
    fn full_span_hits_both_signs() {
        let mut rng = Rng::seed_from(12);
        let (mut neg, mut pos) = (false, false);
        for _ in 0..64 {
            let v = rng.range_i64(i64::MIN, i64::MAX);
            neg |= v < 0;
            pos |= v >= 0;
        }
        assert!(neg && pos, "full-span sampling is degenerate");
    }
}
