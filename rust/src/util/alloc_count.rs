//! Counting global allocator — the `ingestion_micro` technique, promoted
//! to a shared type so the perf suite and the allocation-budget tests
//! measure the same thing.
//!
//! The library only *defines* the pass-through allocator; a binary or
//! integration test opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static A: da4ml::util::alloc_count::CountingAlloc = da4ml::util::alloc_count::CountingAlloc;
//! ```
//!
//! When no binary installs it, [`allocations`] stays at 0 — callers
//! treat a zero reading as "allocator not installed" and skip their
//! gate rather than comparing garbage.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// Pass-through [`System`] allocator that counts allocations and bytes
/// requested (allocs + reallocs; frees are not counted).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocations counted so far (0 when [`CountingAlloc`] is not
/// the process global allocator).
pub fn allocations() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Total bytes requested so far (0 when not installed).
pub fn bytes_requested() -> u64 {
    BYTES.load(Ordering::Relaxed)
}

/// Run `f`, returning its result plus the (allocations, bytes) it made.
/// Both deltas are 0 when the counting allocator is not installed.
/// Process-global counters: concurrent allocations on other threads are
/// attributed to whichever measurement window is open, so callers that
/// need clean numbers measure single-threaded.
pub fn count<T>(f: impl FnOnce() -> T) -> (T, u64, u64) {
    let (a0, b0) = (allocations(), bytes_requested());
    let out = f();
    let (a1, b1) = (allocations(), bytes_requested());
    (out, a1 - a0, b1 - b0)
}
