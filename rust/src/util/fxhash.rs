//! In-tree FxHash (the `rustc_hash` algorithm), vendored so the build
//! stays hermetic: a fast, non-cryptographic, deterministic hasher for
//! the optimizer's interior hash maps. Not DoS-resistant — every map
//! keyed with it holds compiler-internal data, never attacker input.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasher, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The FxHash state: multiply-rotate over 8-byte words.
#[derive(Debug, Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.add_to_hash(i as u64);
        self.add_to_hash((i >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

/// `BuildHasher` producing [`FxHasher`]s (zero-sized, deterministic).
#[derive(Debug, Clone, Copy, Default)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

/// A `HashMap` using FxHash.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// A `HashSet` using FxHash.
pub type FxHashSet<K> = HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_discriminating() {
        assert_eq!(hash_of(&42u64), hash_of(&42u64));
        assert_ne!(hash_of(&42u64), hash_of(&43u64));
        assert_ne!(hash_of(&(1u32, 2i32)), hash_of(&(2u32, 1i32)));
        assert_ne!(hash_of(&"abc"), hash_of(&"abd"));
    }

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<(u32, i32), u32> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert((i, -(i as i32)), i * 2);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u32 {
            assert_eq!(m.get(&(i, -(i as i32))), Some(&(i * 2)));
        }
    }

    #[test]
    fn byte_tail_handled() {
        // Slices whose length is not a multiple of 8 hit the remainder path.
        assert_ne!(hash_of(&[1u8, 2, 3]), hash_of(&[1u8, 2, 4]));
        assert_ne!(hash_of(&vec![9u8; 7]), hash_of(&vec![9u8; 9]));
    }
}
