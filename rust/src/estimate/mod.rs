//! Analytic FPGA resource and timing model — the Vivado/Vitis
//! post-synthesis-report substitute of this reproduction (see DESIGN.md
//! §3 for the substitution argument).
//!
//! LUT costs follow the paper's Eq. (1) exactly: an adder
//! `a ± (b << s)` costs `max(bw_a, bw_b + s) - min(0, s) + 1` LUTs (the
//! number of output bits conditioned on more than one input, i.e. the
//! full/half-adder count). Delay is modeled as adder depth times a
//! per-level unit plus a routing constant, following the paper's
//! "majority of the delay is routing; assume each adder has the same
//! delay" simplification (§3). The constants below are calibrated once
//! against the paper's Table 3 and then frozen for every experiment.

use crate::dais::{DaisOp, DaisProgram, RoundMode};

/// Calibrated device/timing constants (xcvu13p-flga2577-2-e class).
#[derive(Debug, Clone, Copy)]
pub struct FpgaModel {
    /// Combinational delay per adder level, ns.
    pub t_level_ns: f64,
    /// Fixed routing + register overhead per path, ns.
    pub t_route_ns: f64,
    /// Extra ns per adder output bit beyond 8 (wide carry chains).
    pub t_carry_ns_per_bit: f64,
    /// LUTs per flip-flop-stage mux for ReLU, per bit.
    pub relu_lut_per_bit: f64,
}

impl Default for FpgaModel {
    fn default() -> Self {
        Self {
            t_level_ns: 0.30,
            t_route_ns: 0.65,
            t_carry_ns_per_bit: 0.012,
            relu_lut_per_bit: 1.0,
        }
    }
}

/// A Vivado-style utilization + timing report.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceReport {
    /// Look-up tables.
    pub lut: u64,
    /// DSP blocks.
    pub dsp: u64,
    /// Flip-flops.
    pub ff: u64,
    /// Adder/subtractor count.
    pub adders: u64,
    /// Adder depth (combinational levels).
    pub depth: u32,
    /// Combinational (or per-stage critical path) delay in ns.
    pub latency_ns: f64,
    /// Pipeline latency in cycles (1 for a pure combinational block
    /// sandwiched between registers).
    pub latency_cycles: u32,
    /// Achievable clock frequency estimate in MHz.
    pub fmax_mhz: f64,
}

impl ResourceReport {
    /// Merge two reports (resources add; depth/latency take the max —
    /// used when composing independent blocks side by side).
    pub fn parallel(&self, other: &Self) -> Self {
        Self {
            lut: self.lut + other.lut,
            dsp: self.dsp + other.dsp,
            ff: self.ff + other.ff,
            adders: self.adders + other.adders,
            depth: self.depth.max(other.depth),
            latency_ns: self.latency_ns.max(other.latency_ns),
            latency_cycles: self.latency_cycles.max(other.latency_cycles),
            fmax_mhz: if self.fmax_mhz == 0.0 {
                other.fmax_mhz
            } else if other.fmax_mhz == 0.0 {
                self.fmax_mhz
            } else {
                self.fmax_mhz.min(other.fmax_mhz)
            },
        }
    }
}

/// Eq. (1): LUT cost of one two-operand addition. `bw_*` are operand
/// widths, `s` the relative shift of operand b w.r.t. operand a
/// (may be negative after LSB alignment).
pub fn adder_cost(bw_a: u32, bw_b: u32, s: i32) -> u64 {
    if bw_a == 0 || bw_b == 0 {
        return 0; // degenerate: wiring only
    }
    let c = (bw_a as i64).max(bw_b as i64 + s as i64) - (s as i64).min(0) + 1;
    c.max(1) as u64
}

/// LUT cost of one DAIS op (Eq. 1 for adders; width-proportional for
/// muxes; zero for wiring).
pub fn op_lut(program: &DaisProgram, id: u32, model: &FpgaModel) -> u64 {
    let node = &program.nodes[id as usize];
    match node.op {
        DaisOp::Input { .. } | DaisOp::Const { .. } => 0,
        DaisOp::AddShift { a, b, shift_a, shift_b, .. } => {
            let qa = program.nodes[a as usize].qint;
            let qb = program.nodes[b as usize].qint;
            // Align on a's LSB: s = relative shift of b.
            let la = qa.lsb() + shift_a as i32;
            let lb = qb.lsb() + shift_b as i32;
            adder_cost(qa.width(), qb.width(), lb - la)
        }
        DaisOp::Neg { a } => {
            let w = program.nodes[a as usize].qint.width();
            (w + 1) as u64
        }
        DaisOp::Relu { a } => {
            let w = program.nodes[a as usize].qint.width();
            (w as f64 * model.relu_lut_per_bit) as u64
        }
        DaisOp::Quant { a, round, .. } => match round {
            RoundMode::Floor => {
                // Truncation is wiring; clipping costs ~1 LUT per kept bit.
                (node.qint.width() / 2) as u64
            }
            RoundMode::HalfUp => {
                let w = program.nodes[a as usize].qint.width();
                (w + 1) as u64
            }
        },
    }
}

/// Per-level combinational delay of a node (ns).
fn op_delay(program: &DaisProgram, id: u32, model: &FpgaModel) -> f64 {
    let node = &program.nodes[id as usize];
    let w = node.qint.width() as f64;
    match node.op {
        DaisOp::Input { .. } | DaisOp::Const { .. } => 0.0,
        DaisOp::AddShift { .. } | DaisOp::Neg { .. } | DaisOp::Quant { .. } => {
            model.t_level_ns + model.t_carry_ns_per_bit * (w - 8.0).max(0.0)
        }
        DaisOp::Relu { .. } => 0.5 * model.t_level_ns,
    }
}

/// Report for a *combinational* program (one cycle, registers only at
/// the boundary) — the setting of the paper's Tables 3 and 4.
pub fn combinational(program: &DaisProgram, model: &FpgaModel) -> ResourceReport {
    let lut: u64 = (0..program.nodes.len() as u32).map(|i| op_lut(program, i, model)).sum();
    // Critical path: longest chain of op delays.
    let mut path = vec![0f64; program.nodes.len()];
    for (i, node) in program.nodes.iter().enumerate() {
        let base = node
            .op
            .operands()
            .map(|p| path[p as usize])
            .fold(0.0, f64::max);
        path[i] = base + op_delay(program, i as u32, model);
    }
    let crit = program
        .outputs
        .iter()
        .map(|o| path[o.node as usize])
        .fold(0.0, f64::max);
    let latency_ns = crit + model.t_route_ns;
    // Boundary FFs: inputs + outputs registered once.
    let in_ff: u64 = program
        .nodes
        .iter()
        .filter(|n| matches!(n.op, DaisOp::Input { .. }))
        .map(|n| n.qint.width() as u64)
        .sum();
    let out_ff: u64 = program
        .outputs
        .iter()
        .map(|o| program.nodes[o.node as usize].qint.width() as u64)
        .sum();
    ResourceReport {
        lut,
        dsp: 0,
        ff: in_ff + out_ff,
        adders: program.adder_count() as u64,
        depth: program.adder_depth(),
        latency_ns,
        latency_cycles: 1,
        fmax_mhz: 1000.0 / latency_ns,
    }
}

/// Report for a *pipelined* program given a stage assignment (from
/// [`crate::pipeline::assign_stages`]).
pub fn pipelined(program: &DaisProgram, stages: &[u32], model: &FpgaModel) -> ResourceReport {
    assert_eq!(stages.len(), program.nodes.len());
    let lut: u64 = (0..program.nodes.len() as u32).map(|i| op_lut(program, i, model)).sum();

    // Per-stage critical path.
    let mut path = vec![0f64; program.nodes.len()];
    let mut worst: f64 = 0.0;
    for (i, node) in program.nodes.iter().enumerate() {
        let base = node
            .op
            .operands()
            .map(|p| if stages[p as usize] == stages[i] { path[p as usize] } else { 0.0 })
            .fold(0.0, f64::max);
        path[i] = base + op_delay(program, i as u32, model);
        worst = worst.max(path[i]);
    }
    let stage_ns = worst + model.t_route_ns;

    let latency = program
        .outputs
        .iter()
        .map(|o| stages[o.node as usize])
        .max()
        .unwrap_or(0);

    // FFs: each producer holds a delay line as long as its furthest
    // consumer's stage gap (shared across consumers), plus output regs.
    let mut regs = vec![0u32; program.nodes.len()];
    for (i, node) in program.nodes.iter().enumerate() {
        for p in node.op.operands() {
            regs[p as usize] = regs[p as usize].max(stages[i] - stages[p as usize]);
        }
    }
    for o in &program.outputs {
        regs[o.node as usize] = regs[o.node as usize].max(latency - stages[o.node as usize] + 1);
    }
    let ff: u64 = program
        .nodes
        .iter()
        .zip(&regs)
        .map(|(n, &r)| n.qint.width() as u64 * r as u64)
        .sum();

    ResourceReport {
        lut,
        dsp: 0,
        ff,
        adders: program.adder_count() as u64,
        depth: program.adder_depth(),
        latency_ns: stage_ns * (latency + 1) as f64,
        latency_cycles: latency + 1,
        fmax_mhz: 1000.0 / stage_ns,
    }
}

/// Per-stage slice of a pipelined design: the combinational cells that
/// compute on one stage. Produced by [`per_stage`]; the register bits
/// between stages live in the netlist layer
/// ([`crate::netlist::Netlist::reg_bits_per_stage`]), which reports the
/// materialized delay lines of the emitted design.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageReport {
    /// Stage number (0 holds the inputs).
    pub stage: u32,
    /// DAIS nodes assigned to this stage.
    pub cells: u64,
    /// Adder/subtractor count on this stage.
    pub adders: u64,
    /// LUT cost of this stage (Eq. 1 summed over its cells).
    pub lut: u64,
    /// Critical path of this stage in ns (including the routing
    /// constant); the slowest stage sets the clock of the whole design.
    pub crit_ns: f64,
}

/// Per-stage breakdown of a pipelined program — the stage-resolved view
/// of [`pipelined`]. Returns one entry per stage `0..=max(stages)`; the
/// LUT and adder columns sum to the totals [`pipelined`] reports, and
/// the worst `crit_ns` is exactly its clock period.
pub fn per_stage(program: &DaisProgram, stages: &[u32], model: &FpgaModel) -> Vec<StageReport> {
    assert_eq!(stages.len(), program.nodes.len());
    let n_stages = stages.iter().copied().max().unwrap_or(0) as usize + 1;
    let mut out: Vec<StageReport> = (0..n_stages)
        .map(|s| StageReport { stage: s as u32, ..Default::default() })
        .collect();
    let mut path = vec![0f64; program.nodes.len()];
    for (i, node) in program.nodes.iter().enumerate() {
        let base = node
            .op
            .operands()
            .map(|p| if stages[p as usize] == stages[i] { path[p as usize] } else { 0.0 })
            .fold(0.0, f64::max);
        path[i] = base + op_delay(program, i as u32, model);
        let r = &mut out[stages[i] as usize];
        r.cells += 1;
        r.adders += node.op.is_adder() as u64;
        r.lut += op_lut(program, i as u32, model);
        r.crit_ns = r.crit_ns.max(path[i] + model.t_route_ns);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::DaisBuilder;
    use crate::fixed::QInterval;

    #[test]
    fn eq1_cost_examples() {
        // Two aligned 8-bit operands: max(8, 8) + 1 = 9.
        assert_eq!(adder_cost(8, 8, 0), 9);
        // b shifted by 4: max(8, 12) + 1 = 13.
        assert_eq!(adder_cost(8, 8, 4), 13);
        // Negative relative shift: max(8, 8 - 2) + 2 + 1 = 11.
        assert_eq!(adder_cost(8, 8, -2), 11);
        assert_eq!(adder_cost(0, 8, 0), 0);
    }

    fn small_program() -> DaisProgram {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let x0 = b.input(0, q, 0);
        let x1 = b.input(1, q, 0);
        let t = b.add_shift(x0, x1, 1, false);
        let u = b.add_shift(t, x0, 0, true);
        b.output(u, 0);
        b.finish()
    }

    #[test]
    fn combinational_report_sane() {
        let p = small_program();
        let r = combinational(&p, &FpgaModel::default());
        assert_eq!(r.adders, 2);
        assert_eq!(r.depth, 2);
        assert!(r.lut >= 18, "two ~9-11 LUT adders, got {}", r.lut);
        assert!(r.latency_ns > 0.0 && r.fmax_mhz > 0.0);
        assert_eq!(r.dsp, 0);
    }

    #[test]
    fn pipelined_deeper_means_more_ff_higher_fmax() {
        let p = small_program();
        let model = FpgaModel::default();
        let comb = combinational(&p, &model);
        let stages: Vec<u32> = p.nodes.iter().map(|n| n.depth).collect();
        let pip = pipelined(&p, &stages, &model);
        assert!(pip.fmax_mhz > comb.fmax_mhz);
        assert!(pip.ff > 0);
        assert_eq!(pip.latency_cycles, 3);
        assert_eq!(pip.lut, comb.lut);
    }

    #[test]
    fn per_stage_sums_to_pipelined_totals() {
        let p = small_program();
        let model = FpgaModel::default();
        let stages: Vec<u32> = p.nodes.iter().map(|n| n.depth).collect();
        let pip = pipelined(&p, &stages, &model);
        let by_stage = per_stage(&p, &stages, &model);
        assert_eq!(by_stage.len(), 3);
        assert_eq!(by_stage.iter().map(|r| r.lut).sum::<u64>(), pip.lut);
        assert_eq!(by_stage.iter().map(|r| r.adders).sum::<u64>(), pip.adders);
        assert_eq!(
            by_stage.iter().map(|r| r.cells).sum::<u64>(),
            p.nodes.len() as u64
        );
        // The slowest stage sets the clock.
        let worst = by_stage.iter().map(|r| r.crit_ns).fold(0.0, f64::max);
        assert!((worst - 1000.0 / pip.fmax_mhz).abs() < 1e-9);
        // Inputs live on stage 0; both adders are split across 1 and 2.
        assert_eq!(by_stage[0].adders, 0);
        assert_eq!(by_stage[1].adders + by_stage[2].adders, 2);
    }

    #[test]
    fn parallel_merge() {
        let p = small_program();
        let r = combinational(&p, &FpgaModel::default());
        let m = r.parallel(&r);
        assert_eq!(m.lut, 2 * r.lut);
        assert_eq!(m.depth, r.depth);
        assert_eq!(m.latency_cycles, r.latency_cycles);
    }
}
