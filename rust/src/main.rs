//! da4ml CLI — the L3 leader entrypoint.
//!
//! Subcommands mirror the library's main flows (hand-rolled arg parsing;
//! the offline build has no clap):
//!
//! * `compile`  — optimize a CMVM (random) and print the solution summary;
//! * `net`      — compile a network artifact with a strategy and print
//!   the resource report;
//! * `rtl`      — emit Verilog/VHDL for a network;
//! * `simulate` — run a network on test vectors, report accuracy;
//! * `golden`   — cross-check the bit-exact integer simulation against
//!   the golden model (PJRT-executed HLO with `--features pjrt`; the
//!   pure-Rust golden backend plus exported vectors by default);
//! * `serve`    — long-lived JSONL compile service: jobs in on stdin
//!   (or `--input`), solution reports out on stdout, batched through
//!   the coordinator's cache + worker pool (wire format:
//!   `docs/serve.md`);
//! * `explore`  — design-space exploration: sweep strategy × dc ×
//!   pipeline candidates for a network (or CMVM) and report the
//!   non-dominated LUT/FF/latency Pareto front, bit-identical for any
//!   `--jobs` value (`docs/explore.md`).

use anyhow::{bail, Result};
use da4ml::cmvm::{self, CmvmProblem, OptimizeOptions, Strategy};
use da4ml::estimate::{self, FpgaModel};
use da4ml::nn::{self, NetworkSpec, TestVectors};
use da4ml::pipeline::{self, PipelineConfig};
use da4ml::runtime;
use da4ml::util::alloc_count::CountingAlloc;
use da4ml::util::Rng;

/// Count every heap allocation so `perf` can report and gate
/// `allocs_per_compile` (a passthrough to the system allocator with a
/// relaxed atomic bump — negligible overhead on the other subcommands).
#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Minimal flag parser: `--key value` pairs after positional args.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // Bare boolean flags (`--smoke`) are followed by another
                // flag or nothing; only consume a value token otherwise.
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        it.next().cloned().unwrap_or_else(|| "true".into())
                    }
                    _ => "true".into(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
        }
        Self { positional, flags }
    }

    fn flag<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn pos(&self, i: usize, what: &str) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing argument: {what}"))
    }
}

fn parse_strategy(s: &str, dc: i32) -> Strategy {
    match s {
        "latency" => Strategy::Latency,
        "naive-da" => Strategy::NaiveDa,
        "cse-only" => Strategy::CseOnly { dc },
        "lookahead" => Strategy::Lookahead { dc },
        _ => Strategy::Da { dc },
    }
}

/// Set by the SIGTERM/SIGINT handler; polled by the socket server's
/// accept loop to start a graceful drain.
static TERM_REQUESTED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_term(_signum: i32) {
    // Only async-signal-safe work here: set the flag, nothing else.
    TERM_REQUESTED.store(true, std::sync::atomic::Ordering::SeqCst);
}

fn term_requested() -> bool {
    TERM_REQUESTED.load(std::sync::atomic::Ordering::SeqCst)
}

/// Route SIGTERM and SIGINT to the drain flag. Raw libc `signal` —
/// the offline build has no `signal-hook`/`ctrlc` crate, and a
/// one-shot boolean handler is all the drain protocol needs.
fn install_term_handler() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_term);
        signal(SIGINT, on_term);
    }
}

fn load_spec(path: &str) -> Result<NetworkSpec> {
    NetworkSpec::from_json(&runtime::load_text(path)?)
}

/// Start a trace session when `--trace-out <file>` was passed (perf /
/// explore / serve). Tracing stays fully disabled — one relaxed atomic
/// check per would-be span — without the flag.
fn begin_trace(args: &Args) -> Option<da4ml::obs::TraceSession> {
    args.flags.get("trace-out").map(|path| da4ml::obs::begin_trace(path))
}

/// Finish a `--trace-out` session: export the Chrome trace (or JSONL
/// event log, by extension) plus the metrics snapshot sibling.
fn finish_trace(session: Option<da4ml::obs::TraceSession>) -> Result<()> {
    if let Some(session) = session {
        let (trace, metrics) = session.finish()?;
        eprintln!("trace: wrote {trace} (events) and {metrics} (metrics snapshot)");
    }
    Ok(())
}

/// An active `serve --trace-out` session, buffered or streaming.
enum ServeTrace {
    /// Chrome-trace (`.json`) output: events buffer in memory and are
    /// written once at exit (same as every other subcommand).
    Buffered(da4ml::obs::TraceSession),
    /// JSONL (`.jsonl`) output: events stream to disk incrementally
    /// with optional size rotation — the long-lived-server mode, where
    /// buffering until exit is not an option.
    Streaming(da4ml::obs::StreamingTraceSession),
}

/// Start a `serve` trace session when `--trace-out <file>` was passed:
/// a `.jsonl` path streams (and honours `--trace-rotate-mb`), anything
/// else buffers like [`begin_trace`].
fn begin_serve_trace(args: &Args) -> Result<Option<ServeTrace>> {
    let rotate_mb = match args.flags.get("trace-rotate-mb") {
        Some(v) => {
            Some(v.parse::<u64>().map_err(|e| anyhow::anyhow!("--trace-rotate-mb {v}: {e}"))?)
        }
        None => None,
    };
    let Some(path) = args.flags.get("trace-out") else {
        anyhow::ensure!(rotate_mb.is_none(), "--trace-rotate-mb requires --trace-out");
        return Ok(None);
    };
    if path.ends_with(".jsonl") {
        let cfg = da4ml::obs::StreamConfig {
            path: path.clone(),
            rotate_bytes: rotate_mb.map(|mb| mb.max(1) * 1024 * 1024),
        };
        Ok(Some(ServeTrace::Streaming(da4ml::obs::StreamingTraceSession::begin(cfg)?)))
    } else {
        anyhow::ensure!(
            rotate_mb.is_none(),
            "--trace-rotate-mb needs a .jsonl --trace-out: rotation streams events \
             incrementally, while a Chrome trace is buffered and written once at exit"
        );
        Ok(Some(ServeTrace::Buffered(da4ml::obs::begin_trace(path))))
    }
}

/// Finish a `serve` trace session, reporting where the events went.
fn finish_serve_trace(session: Option<ServeTrace>) -> Result<()> {
    match session {
        None => Ok(()),
        Some(ServeTrace::Buffered(s)) => {
            let (trace, metrics) = s.finish()?;
            eprintln!("trace: wrote {trace} (events) and {metrics} (metrics snapshot)");
            Ok(())
        }
        Some(ServeTrace::Streaming(s)) => {
            let (trace, metrics) = s.finish()?;
            eprintln!("trace: streamed {trace} (events) and wrote {metrics} (metrics snapshot)");
            Ok(())
        }
    }
}

/// Parse one or more JSONL trace logs into a single event list. Files
/// concatenate in argument order (pass a rotated `.1` file before its
/// live sibling to keep timestamps monotonic); `dropped_events` is the
/// max over the inputs, since the counter is cumulative per process.
fn load_logs(paths: &[String]) -> Result<da4ml::obs::analyze::ParsedLog> {
    anyhow::ensure!(!paths.is_empty(), "need at least one trace log (a .jsonl event file)");
    let mut merged = da4ml::obs::analyze::ParsedLog::default();
    for path in paths {
        let text = runtime::load_text(path)?;
        let log = da4ml::obs::analyze::parse_log(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
        merged.events.extend(log.events);
        merged.dropped_events = merged.dropped_events.max(log.dropped_events);
    }
    Ok(merged)
}

fn load_vectors(path: &str) -> Result<TestVectors> {
    TestVectors::from_json(&runtime::load_text(path)?)
}

const USAGE: &str = "usage: da4ml <compile|net|rtl|simulate|golden|verify|dot|serve|perf|explore|cache|obs>
  compile [--d-in N] [--d-out N] [--bits B] [--dc D] [--seed S]
  net <spec.weights.json> [--strategy da|latency|naive-da] [--dc D] [--pipe N]
  rtl <spec.weights.json> <out.v|out.vhd> [--pipe N] [--dc D] [--tb testvec.json]
      (prints netlist stats + per-stage table; --tb also writes a
       self-checking Verilog testbench next to the DUT)
  simulate <spec.weights.json> <spec.testvec.json>
  golden <spec.weights.json> <spec.hlo.txt> <spec.testvec.json>
  verify <spec.weights.json> [--dc D]      (well-formedness + bit-exactness)
  dot <spec.weights.json> <out.dot> [--dc D]  (Graphviz adder graph)
  serve [--input jobs.jsonl] [--batch N] [--dc D] [--threads T] [--cache-cap N]
        [--cache-shards N] [--cache-load cache.json] [--cache-save cache.json]
        [--trace-out trace.json|trace.jsonl [--trace-rotate-mb N]]
        [--socket /path.sock [--listen host:port] [--workers N]
         [--stats-every N] [--max-inflight N] [--conn-inflight N]]
        [--connect /path.sock|host:port]
        (JSONL compile service: jobs on stdin or --input, reports on
         stdout, summary on stderr; --socket starts the concurrent
         socket server instead — Unix socket always, TCP with --listen,
         many clients over one shared cache, busy replies past
         --max-inflight, graceful drain on SIGTERM/SIGINT or a
         {\"type\": \"shutdown\"} control line; --connect streams jobs
         to a running server and prints its replies; --cache-cap bounds
         the solution cache with LRU eviction, --cache-shards splits it
         across independently locked shards, --cache-load/--cache-save
         restart the service warm; --trace-out records a Chrome trace +
         metrics snapshot — a .jsonl path streams events incrementally
         instead, with size rotation via --trace-rotate-mb (live file +
         one rotated .1 predecessor), see docs/observability.md; wire
         format in docs/serve.md)
  perf [--smoke] [--runs N] [--out BENCH_cmvm.json] [--trace-out trace.json]
       [--baseline ci/bench_baseline.json] [--bless file] [--with-times]
       (fixed benchmark suite over optimize/lower/emit + the CSE engine
        A/B; writes the schema-versioned BENCH_cmvm.json, --baseline
        diffs against a committed baseline and exits nonzero on
        regression, --bless writes a new baseline; docs/perf.md)
  explore [<spec.weights.json>] [--smoke] [--jobs N] [--out EXPLORE_report.json]
          [--objective min-lut|min-latency|knee] [--trace-out trace.json]
          [--cmvm [--d-in N] [--d-out N] [--bits B] [--seed S]]
          [--cache-load cache.json] [--cache-save cache.json]
          (design-space exploration: sweeps strategy x dc x pipeline
           candidates and reports the non-dominated LUT/FF/latency
           Pareto front; target is the spec file, a seeded random CMVM
           with --cmvm, or the synthetic jet network by default; output
           is bit-identical for every --jobs value; --cache-load warms
           the shared solution cache, --cache-save persists it after
           the sweep; docs/explore.md)
  cache bake [<spec.weights.json>...] [--corpus jobs.jsonl] [--strategy S]
             [--dc D] [--shards N] [--threads T] [--out cache.json]
        (compile every layer of each spec — or every corpus job — and
         save the solution cache; the synthetic jet network when
         neither is given)
  cache info <cache.json>            (validate + summarize a cache file)
  cache merge <out.json> <in.json...>
        (union of the inputs; earlier files win on key clashes;
         persistence format + workflow in docs/cache.md)
  obs report <trace.jsonl...>        (per-span count/p50/p99/total table)
  obs critical-path <trace.jsonl...>
        (per-trace decode -> queue_wait -> execute -> write stage path,
         one row per trace id; exits nonzero on structural problems)
  obs diff <baseline.jsonl> <candidate.jsonl> [--time-tolerance F]
        (compare two trace logs span-by-span with perf-gate tolerances;
         exits nonzero on regression)
  obs check <trace.jsonl...>         (structural validation: span ids,
        parent links, interval containment; exits nonzero on errors)
        (obs reads JSONL event logs from serve --trace-out x.jsonl;
         multiple logs concatenate in argument order — list a rotated
         .1 file before its live sibling; docs/observability.md)";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "compile" => {
            let d_in: usize = args.flag("d-in", 16);
            let d_out: usize = args.flag("d-out", 16);
            let bits: u32 = args.flag("bits", 8);
            let dc: i32 = args.flag("dc", -1);
            let seed: u64 = args.flag("seed", 0);
            let mut rng = Rng::seed_from(seed);
            let lo = (1i64 << (bits - 1)) + 1;
            let hi = (1i64 << bits) - 1;
            let m: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(lo, hi)).collect();
            let p = CmvmProblem::new(d_in, d_out, m, 8)?;
            let sol = cmvm::compile(&p, &OptimizeOptions::new(Strategy::Da { dc }))?;
            let rep = estimate::combinational(&sol.program, &FpgaModel::default());
            println!(
                "CMVM {d_in}x{d_out} {bits}-bit dc={dc}: adders={} depth={} lut={} \
                 latency={:.2}ns opt_time={:?}",
                sol.adders, sol.depth, rep.lut, rep.latency_ns, sol.opt_time
            );
        }
        "net" => {
            let spec = load_spec(args.pos(0, "spec path")?)?;
            let dc: i32 = args.flag("dc", 2);
            let s = parse_strategy(&args.flag::<String>("strategy", "da".into()), dc);
            let pipe: u32 = args.flag("pipe", 5);
            let model = FpgaModel::default();
            // --pipe 0 used to be silently clamped to 1; it is a proper
            // error now (rtl's --pipe 0 still means "combinational").
            let cfg = PipelineConfig::try_every_n_adders(pipe)?;
            let reports = nn::compile::layer_reports(&spec, s, &model, &cfg)?;
            let mut table = da4ml::report::Table::new(
                &format!("{} ({})", spec.name, s.name()),
                &["layer", "inst", "LUT", "DSP", "FF", "adders"],
            );
            for r in &reports {
                table.push(vec![
                    r.name.clone(),
                    r.instances.to_string(),
                    r.total.lut.to_string(),
                    r.total.dsp.to_string(),
                    r.total.ff.to_string(),
                    r.total.adders.to_string(),
                ]);
            }
            let agg = nn::compile::aggregate(&reports);
            table.push(vec![
                "TOTAL".into(),
                "-".into(),
                agg.lut.to_string(),
                agg.dsp.to_string(),
                agg.ff.to_string(),
                agg.adders.to_string(),
            ]);
            println!("{}", table.render());
        }
        "rtl" => {
            let spec = load_spec(args.pos(0, "spec path")?)?;
            let out = args.pos(1, "output path")?;
            let pipe: u32 = args.flag("pipe", 5);
            let dc: i32 = args.flag("dc", 2);
            let opts = nn::compile::CompileOptions::new(Strategy::Da { dc });
            let prog = nn::compile::compile(&spec, &opts)?.program;
            // Both backends are netlist walks now, so VHDL pipelines
            // too; lower once and reuse for emission, stats and the
            // testbench.
            let stages = (pipe > 0)
                .then(|| pipeline::assign_stages(&prog, &PipelineConfig::every_n_adders(pipe)));
            let nl = da4ml::netlist::Netlist::lower(&prog, stages.as_deref())?;
            let vhdl = out.ends_with(".vhd") || out.ends_with(".vhdl");
            let text = if vhdl {
                da4ml::rtl::vhdl_from_netlist(&nl, &spec.name)
            } else {
                da4ml::rtl::verilog_from_netlist(&nl, &spec.name)
            };
            std::fs::write(out, text)?;
            println!(
                "wrote {out}: {} cells ({} adders), {} wires, {} register bits, \
                 latency {} cycles",
                nl.cells.len(),
                nl.adder_count(),
                nl.wires.len(),
                nl.reg_bits(),
                nl.latency
            );
            if let Some(st) = &stages {
                let table =
                    da4ml::netlist::stats::stage_table(&nl, &prog, st, &FpgaModel::default());
                println!("{}", table.render());
            }
            if let Some(tb_path) = args.flags.get("tb") {
                let vecs = load_vectors(tb_path)?;
                let tb =
                    da4ml::netlist::testbench::emit_testbench(&nl, &spec.name, &vecs, 64)?;
                let tb_out = format!("{out}.tb.v");
                std::fs::write(&tb_out, tb)?;
                println!(
                    "wrote {tb_out}: self-checking testbench ({} vectors)",
                    vecs.inputs.len().min(64)
                );
                if vhdl {
                    println!(
                        "note: the testbench is Verilog; it instantiates the *Verilog* \
                         emission of this netlist (re-run with a .v output, or use a \
                         mixed-language simulator)"
                    );
                }
            }
        }
        "simulate" => {
            let spec = load_spec(args.pos(0, "spec path")?)?;
            let vecs = load_vectors(args.pos(1, "testvec path")?)?;
            let outs = nn::sim::forward_batch(&spec, &vecs.inputs);
            let exact = outs.iter().zip(&vecs.outputs).filter(|(a, b)| a == b).count();
            println!(
                "{}: {}/{} outputs bit-exact vs exported golden",
                spec.name,
                exact,
                outs.len()
            );
            if !vecs.labels.is_empty() {
                println!("accuracy: {:.4}", nn::sim::accuracy(&outs, &vecs.labels));
            }
        }
        "golden" => {
            let spec = load_spec(args.pos(0, "spec path")?)?;
            let hlo = args.pos(1, "hlo path")?;
            let vecs = load_vectors(args.pos(2, "testvec path")?)?;
            // Validate the vectors up front: a malformed file must fail
            // loudly, not truncate the comparison into a false pass or
            // panic inside the simulator.
            anyhow::ensure!(
                vecs.outputs.len() == vecs.inputs.len(),
                "testvec: {} outputs for {} inputs",
                vecs.outputs.len(),
                vecs.inputs.len()
            );
            for (i, x) in vecs.inputs.iter().enumerate() {
                anyhow::ensure!(
                    x.len() == spec.input_len(),
                    "testvec input {i}: length {} != spec input length {}",
                    x.len(),
                    spec.input_len()
                );
            }
            let n = vecs.inputs.len().min(32);
            #[cfg(feature = "pjrt")]
            {
                let rt = runtime::Runtime::cpu()?;
                let model = rt.load_hlo_text(hlo)?;
                let weights = nn::weight_tensors(&spec);
                let mut mismatches = 0;
                for x in &vecs.inputs[..n] {
                    let mut args = vec![runtime::TensorI32::new(
                        x.iter().map(|&v| v as i32).collect(),
                        vec![x.len() as i64],
                    )];
                    args.extend(weights.iter().cloned());
                    let golden = model.run_i32(&args)?;
                    let sim = nn::sim::forward(&spec, x);
                    let g: Vec<i64> = golden[0].data.iter().map(|&v| v as i64).collect();
                    if g != sim {
                        mismatches += 1;
                    }
                }
                println!(
                    "golden cross-check ({} on {}): {}/{} match",
                    spec.name,
                    rt.platform(),
                    n - mismatches,
                    n
                );
            }
            #[cfg(not(feature = "pjrt"))]
            {
                // Default build: the pure-Rust golden backend replays the
                // spec; cross-check it against the *exported* vectors
                // (the JAX-side golden data), ignoring the HLO path.
                let _ = hlo;
                let golden = runtime::golden::GoldenModel::from_spec(spec.clone());
                let mut mismatches = 0;
                for (x, want) in vecs.inputs[..n].iter().zip(&vecs.outputs) {
                    if &golden.run(x) != want {
                        mismatches += 1;
                    }
                }
                println!(
                    "golden cross-check ({} on golden-sim; rebuild with --features pjrt \
                     for PJRT): {}/{} match exported vectors",
                    spec.name,
                    n - mismatches,
                    n
                );
            }
        }
        "verify" => {
            let spec = load_spec(args.pos(0, "spec path")?)?;
            let dc: i32 = args.flag("dc", 2);
            let opts = nn::compile::CompileOptions::new(Strategy::Da { dc });
            let prog = nn::compile::compile(&spec, &opts)?.program;
            da4ml::dais::verify::check_well_formed(&prog)?;
            // Cross-check DAIS vs the bit-exact host simulator on random
            // in-range inputs.
            let mut rng = Rng::seed_from(7);
            let q = spec.input_qint();
            for _ in 0..64 {
                let x: Vec<i64> =
                    (0..spec.input_len()).map(|_| rng.range_i64(q.min, q.max)).collect();
                let dais = da4ml::dais::interp::evaluate_checked(&prog, &x);
                let host = nn::sim::forward(&spec, &x);
                anyhow::ensure!(dais == host, "DAIS != host sim on {x:?}");
            }
            println!(
                "{}: well-formed, {} adders, depth {}, 64/64 random vectors bit-exact",
                spec.name,
                prog.adder_count(),
                prog.adder_depth()
            );
        }
        "dot" => {
            let spec = load_spec(args.pos(0, "spec path")?)?;
            let out = args.pos(1, "output path")?;
            let dc: i32 = args.flag("dc", 2);
            let opts = nn::compile::CompileOptions::new(Strategy::Da { dc });
            let prog = nn::compile::compile(&spec, &opts)?.program;
            std::fs::write(out, da4ml::dais::dot::to_dot(&prog, &spec.name))?;
            println!("wrote {out} ({} nodes)", prog.nodes.len());
        }
        "perf" => {
            let trace = begin_trace(&args);
            let base = if args.flags.contains_key("smoke") {
                da4ml::perf::PerfConfig::smoke()
            } else {
                da4ml::perf::PerfConfig::full()
            };
            let cfg = da4ml::perf::PerfConfig {
                runs: args.flag("runs", base.runs).max(1),
                ..base
            };
            let report = da4ml::perf::run_suite(&cfg)?;
            finish_trace(trace)?;
            println!("{}", da4ml::perf::render_table(&report));
            let out = args.flag::<String>("out", "BENCH_cmvm.json".into());
            std::fs::write(&out, da4ml::perf::schema::render(&report))?;
            println!(
                "wrote {out}: schema v{}, {} cases ({} skipped), engine A/B speedup {:.2}x",
                report.schema_version,
                report.cases.len(),
                report.skipped.len(),
                report.engine_ab.speedup
            );
            if let Some(path) = args.flags.get("bless") {
                let with_times = args.flags.contains_key("with-times");
                std::fs::write(
                    path,
                    da4ml::perf::schema::render_baseline(&report, with_times),
                )?;
                println!(
                    "blessed baseline {path} ({} cases pinned{})",
                    report.cases.len(),
                    if with_times { ", with times" } else { "" }
                );
            }
            if let Some(path) = args.flags.get("baseline") {
                let text = runtime::load_text(path)?;
                let baseline = da4ml::perf::schema::parse_baseline(&text)
                    .map_err(|e| anyhow::anyhow!("parsing baseline {path}: {e}"))?;
                let diff = da4ml::perf::diff::against_baseline(&report, &baseline);
                for n in &diff.notes {
                    println!("note: {n}");
                }
                if diff.passed() {
                    println!(
                        "perf gate: OK ({} metrics checked against {path})",
                        diff.checked
                    );
                } else {
                    for r in &diff.regressions {
                        eprintln!("REGRESSION: {r}");
                    }
                    bail!(
                        "perf gate: {} regression(s) vs {path}",
                        diff.regressions.len()
                    );
                }
            }
        }
        "explore" => {
            let jobs: usize = args.flag("jobs", 0usize);
            let space = if args.flags.contains_key("smoke") {
                da4ml::explore::SpaceConfig::smoke()
            } else {
                da4ml::explore::SpaceConfig::full()
            };
            let cfg = da4ml::explore::ExploreConfig { space, jobs, model: FpgaModel::default() };
            let target = if let Some(path) = args.positional.first() {
                da4ml::explore::ExploreTarget::Network(load_spec(path)?)
            } else if args.flags.contains_key("cmvm") {
                let d_in: usize = args.flag("d-in", 8);
                let d_out: usize = args.flag("d-out", 8);
                let bits: u32 = args.flag("bits", 8);
                let seed: u64 = args.flag("seed", 0);
                da4ml::explore::ExploreTarget::Cmvm(CmvmProblem::random(seed, d_in, d_out, bits))
            } else {
                // The CI smoke target: the synthetic jet network.
                da4ml::explore::ExploreTarget::Network(da4ml::bench_tables::synthetic_jet_spec())
            };
            let coord = da4ml::coordinator::Coordinator::new();
            if let Some(path) = args.flags.get("cache-load") {
                let text = runtime::load_text(path)?;
                let n = coord
                    .load_cache(&text)
                    .map_err(|e| anyhow::anyhow!("loading cache {path}: {e:#}"))?;
                println!("explore: warm start: loaded {n} solutions from {path}");
            }
            let trace = begin_trace(&args);
            let report = da4ml::explore::explore(&target, &coord, &cfg)?;
            finish_trace(trace)?;
            if let Some(path) = args.flags.get("cache-save") {
                std::fs::write(path, coord.save_cache())?;
                println!(
                    "explore: saved {} cache entries to {path}",
                    coord.cache_len()
                );
            }
            println!("{}", da4ml::explore::render_table(&report));
            let objective = da4ml::explore::Objective::parse(
                &args.flag::<String>("objective", "knee".into()),
            )?;
            if let Some(p) = da4ml::explore::pick(&report.front, objective) {
                println!(
                    "picked ({}): {} — {} LUT, {} FF, {:.2} ns ({} cycles)",
                    objective.name(),
                    p.id,
                    p.lut,
                    p.ff,
                    p.latency_ns,
                    p.latency_cycles
                );
            }
            let out = args.flag::<String>("out", "EXPLORE_report.json".into());
            std::fs::write(&out, da4ml::explore::schema::render(&report))?;
            println!(
                "wrote {out}: schema v{}, {} front / {} dominated / {} skipped",
                report.schema_version,
                report.front.len(),
                report.dominated.len(),
                report.skipped.len()
            );
        }
        "serve" => {
            // Thin client mode: stream jobs to a running socket server
            // and print its reply stream (same bytes the stdin
            // transport would produce for the same jobs).
            if let Some(target) = args.flags.get("connect") {
                let stdout = std::io::stdout();
                let mut out = std::io::BufWriter::new(stdout.lock());
                match args.flags.get("input") {
                    Some(path) => {
                        let file = std::fs::File::open(path)
                            .map_err(|e| anyhow::anyhow!("opening {path}: {e}"))?;
                        da4ml::serve::server::run_client(
                            target,
                            std::io::BufReader::new(file),
                            &mut out,
                        )?;
                    }
                    None => {
                        let stdin = std::io::stdin();
                        da4ml::serve::server::run_client(target, stdin.lock(), &mut out)?;
                    }
                }
                return Ok(());
            }
            let cache_cap = match args.flags.get("cache-cap") {
                Some(v) => Some(
                    v.parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("--cache-cap {v}: {e}"))?,
                ),
                None => None,
            };
            let cfg = da4ml::serve::ServeConfig {
                batch_size: args.flag("batch", 16usize),
                threads: args.flag("threads", 0usize),
                default_dc: args.flag("dc", -1i32),
                cache_cap,
                cache_shards: args.flag("cache-shards", 1usize).max(1),
                ..da4ml::serve::ServeConfig::default()
            };
            // The CLI owns the coordinator (not `serve`) so the cache
            // can be loaded before the first job and saved after EOF.
            let coord = da4ml::coordinator::Coordinator::with_shards(cfg.cache_shards);
            coord.set_cache_cap(cfg.cache_cap);
            if let Some(path) = args.flags.get("cache-load") {
                let text = runtime::load_text(path)?;
                let n = coord
                    .load_cache(&text)
                    .map_err(|e| anyhow::anyhow!("loading cache {path}: {e:#}"))?;
                eprintln!("serve: warm start: loaded {n} solutions from {path}");
            }
            let trace = begin_serve_trace(&args)?;
            // Socket server mode: many concurrent clients over the
            // same coordinator; drained gracefully by SIGTERM/SIGINT
            // or a shutdown control line from any client.
            if let Some(socket) = args.flags.get("socket") {
                let scfg = da4ml::serve::server::ServerConfig {
                    serve: cfg.clone(),
                    workers: args.flag("workers", 0usize),
                    max_inflight: args.flag("max-inflight", 256usize).max(1),
                    conn_inflight: args.flag("conn-inflight", 32usize).max(1),
                    stats_every: args.flag("stats-every", 0u64),
                    max_line_bytes: args.flag("max-line-bytes", 8usize * 1024 * 1024),
                    write_timeout_ms: args.flag("write-timeout-ms", 30_000u64),
                    drain_when: Some(term_requested),
                };
                install_term_handler();
                let listen = args.flags.get("listen").map(|s| s.as_str());
                let server = da4ml::serve::server::Server::bind(
                    coord.clone(),
                    scfg,
                    std::path::Path::new(socket),
                    listen,
                )?;
                match listen {
                    Some(addr) => eprintln!("serve: listening on {socket} and {addr}"),
                    None => eprintln!("serve: listening on {socket}"),
                }
                let summary = server.run()?;
                eprintln!(
                    "serve: {} client(s), {} jobs, {} replies ({} errors, {} busy-rejected, \
                     {} dropped); {} submitted, {} cache hits, {} loaded, {} evictions over \
                     {} shard(s), {:.1} ms optimizer time",
                    summary.clients,
                    summary.jobs,
                    summary.replies,
                    summary.errors,
                    summary.rejected_busy,
                    summary.dropped_jobs,
                    summary.stats.submitted,
                    summary.stats.cache_hits,
                    summary.stats.loaded,
                    summary.stats.evictions,
                    coord.shard_count(),
                    summary.stats.total_opt_time.as_secs_f64() * 1e3
                );
                if let Some(path) = args.flags.get("cache-save") {
                    std::fs::write(path, coord.save_cache())?;
                    eprintln!("serve: saved {} cache entries to {path}", coord.cache_len());
                }
                finish_serve_trace(trace)?;
                return Ok(());
            }
            if args.flags.contains_key("listen") {
                bail!("--listen requires --socket (the TCP listener is server-mode only)");
            }
            let stdout = std::io::stdout();
            let mut out = std::io::BufWriter::new(stdout.lock());
            let summary = match args.flags.get("input") {
                Some(path) => {
                    let file = std::fs::File::open(path)
                        .map_err(|e| anyhow::anyhow!("opening {path}: {e}"))?;
                    da4ml::serve::serve_with(&coord, std::io::BufReader::new(file), &mut out, &cfg)?
                }
                None => {
                    let stdin = std::io::stdin();
                    da4ml::serve::serve_with(&coord, stdin.lock(), &mut out, &cfg)?
                }
            };
            drop(out);
            eprintln!(
                "serve: {} jobs ({} errors) in {} batches; {} submitted, {} cache hits, \
                 {} loaded, {} evictions over {} shard(s), {:.1} ms optimizer time, \
                 {} CSE steps / {} heap pops",
                summary.jobs,
                summary.errors,
                summary.batches,
                summary.stats.submitted,
                summary.stats.cache_hits,
                summary.stats.loaded,
                summary.stats.evictions,
                coord.shard_count(),
                summary.stats.total_opt_time.as_secs_f64() * 1e3,
                summary.stats.total_cse_steps,
                summary.stats.total_heap_pops
            );
            if let Some(path) = args.flags.get("cache-save") {
                std::fs::write(path, coord.save_cache())?;
                eprintln!(
                    "serve: saved {} cache entries to {path}",
                    coord.cache_len()
                );
            }
            finish_serve_trace(trace)?;
        }
        "obs" => {
            let sub = args.pos(0, "obs subcommand (report|critical-path|diff|check)")?;
            match sub {
                "report" => {
                    let log = load_logs(&args.positional[1..])?;
                    println!("{}", da4ml::obs::analyze::report(&log.events).render());
                    println!(
                        "{} event(s), {} dropped at capture",
                        log.events.len(),
                        log.dropped_events
                    );
                }
                "critical-path" => {
                    let log = load_logs(&args.positional[1..])?;
                    let cp = da4ml::obs::analyze::critical_path(&log.events);
                    println!("{}", cp.table.render());
                    println!("{} trace(s)", cp.traces);
                    if !cp.problems.is_empty() {
                        for p in &cp.problems {
                            eprintln!("problem: {p}");
                        }
                        bail!("obs critical-path: {} problem(s)", cp.problems.len());
                    }
                }
                "diff" => {
                    let base_path = args.pos(1, "baseline trace log")?.to_string();
                    let cand_path = args.pos(2, "candidate trace log")?.to_string();
                    let base = load_logs(&[base_path.clone()])?;
                    let cand = load_logs(&[cand_path.clone()])?;
                    let default_tol = da4ml::obs::analyze::DEFAULT_TIME_TOLERANCE;
                    let tol: f64 = args.flag("time-tolerance", default_tol);
                    let d = da4ml::obs::analyze::diff(&base.events, &cand.events, tol);
                    for n in &d.notes {
                        println!("note: {n}");
                    }
                    if d.passed() {
                        println!(
                            "obs diff: OK ({} metrics checked, {base_path} vs {cand_path})",
                            d.checked
                        );
                    } else {
                        for r in &d.regressions {
                            eprintln!("REGRESSION: {r}");
                        }
                        bail!(
                            "obs diff: {} regression(s), {base_path} vs {cand_path}",
                            d.regressions.len()
                        );
                    }
                }
                "check" => {
                    let log = load_logs(&args.positional[1..])?;
                    let rep = da4ml::obs::analyze::check(&log.events, log.dropped_events);
                    for n in &rep.notes {
                        println!("note: {n}");
                    }
                    if rep.passed() {
                        println!(
                            "obs check: OK ({} event(s), {} dropped at capture)",
                            rep.events, log.dropped_events
                        );
                    } else {
                        for e in &rep.errors {
                            eprintln!("ERROR: {e}");
                        }
                        bail!("obs check: {} error(s)", rep.errors.len());
                    }
                }
                other => bail!(
                    "unknown obs subcommand '{other}' \
                     (expected report|critical-path|diff|check)\n{USAGE}"
                ),
            }
        }
        "cache" => {
            match args.pos(0, "cache subcommand (bake|info|merge)")? {
                "bake" => {
                    let dc: i32 = args.flag("dc", -1);
                    let strategy =
                        parse_strategy(&args.flag::<String>("strategy", "da".into()), dc);
                    let shards: usize = args.flag("shards", 1usize);
                    let coord = da4ml::coordinator::Coordinator::with_shards(shards);
                    let mut jobs = Vec::new();
                    for path in &args.positional[1..] {
                        let spec = load_spec(path)?;
                        jobs.extend(nn::compile::layer_jobs(&spec, strategy)?);
                    }
                    if let Some(path) = args.flags.get("corpus") {
                        let text = runtime::load_text(path)?;
                        for (no, line) in text.lines().enumerate() {
                            if line.trim().is_empty() {
                                continue;
                            }
                            let req = da4ml::serve::JobRequest::from_json(line)
                                .map_err(|e| anyhow::anyhow!("{path}:{}: {e:#}", no + 1))?;
                            let id =
                                req.id.clone().unwrap_or_else(|| format!("job-{}", no + 1));
                            let job = req
                                .to_compile_job(id, dc)
                                .map_err(|e| anyhow::anyhow!("{path}:{}: {e:#}", no + 1))?;
                            jobs.push(job);
                        }
                    }
                    if jobs.is_empty() {
                        // CI-smoke default: the synthetic jet network.
                        let spec = da4ml::bench_tables::synthetic_jet_spec();
                        jobs = nn::compile::layer_jobs(&spec, strategy)?;
                    }
                    let n_jobs = jobs.len();
                    for r in coord.compile_batch(jobs, args.flag("threads", 0usize)) {
                        r?;
                    }
                    let out = args.flag::<String>("out", "cache.json".into());
                    std::fs::write(&out, coord.save_cache())?;
                    let stats = coord.stats();
                    println!(
                        "baked {out}: {} solutions from {n_jobs} jobs ({} cache hits), \
                         {:.1} ms optimizer time",
                        coord.cache_len(),
                        stats.cache_hits,
                        stats.total_opt_time.as_secs_f64() * 1e3
                    );
                }
                "info" => {
                    let path = args.pos(1, "cache file")?;
                    let text = runtime::load_text(path)?;
                    let info = da4ml::coordinator::persist::info(&text)
                        .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
                    println!(
                        "{path}: schema v{}, {} entries, {} adders total",
                        info.schema_version, info.entries, info.total_adders
                    );
                    for (name, n) in &info.by_strategy {
                        println!("  {name}: {n}");
                    }
                }
                "merge" => {
                    let out = args.pos(1, "output cache file")?.to_string();
                    anyhow::ensure!(
                        args.positional.len() > 2,
                        "merge needs at least one input cache file"
                    );
                    let coord = da4ml::coordinator::Coordinator::new();
                    for path in &args.positional[2..] {
                        let text = runtime::load_text(path)?;
                        let n = coord
                            .load_cache(&text)
                            .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
                        println!("loaded {path}: {n} new entries ({} total)", coord.cache_len());
                    }
                    std::fs::write(&out, coord.save_cache())?;
                    println!("merged {} entries into {out}", coord.cache_len());
                }
                other => {
                    bail!("unknown cache subcommand '{other}' (expected bake|info|merge)\n{USAGE}")
                }
            }
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}
