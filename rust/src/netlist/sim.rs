//! Cycle-accurate simulation of a lowered [`Netlist`].
//!
//! Unlike the node-level interpreter ([`crate::dais::interp`]), this
//! simulator executes the *hardware* view: registers clock first, the
//! combinational cloud settles in topological order, and — crucially —
//! every cell result is truncated to its wire's two's-complement width,
//! exactly as the emitted Verilog/VHDL would behave. A netlist whose
//! widths are too narrow therefore diverges from the interpreter, which
//! is what the differential property tests below exploit: bit-exact
//! agreement with [`crate::dais::interp::evaluate_batch`] after the
//! pipeline latency proves both the register placement *and* the wire
//! widths of the emitted design.

use super::{CellOp, Netlist};
use crate::dais::interp::quant_scalar;

/// Truncate `v` to `width`-bit two's complement (sign-extended back to
/// i64) — the value a hardware wire of that width would carry.
#[inline]
fn wrap(v: i64, width: u32) -> i64 {
    if width >= 64 {
        return v;
    }
    let s = 64 - width;
    (v << s) >> s
}

/// Stateful cycle-by-cycle simulator over a netlist.
#[derive(Debug, Clone)]
pub struct Simulator<'a> {
    nl: &'a Netlist,
    values: Vec<i64>,
}

impl<'a> Simulator<'a> {
    /// New simulator with all wires (and registers) at zero.
    pub fn new(nl: &'a Netlist) -> Self {
        Self { nl, values: vec![0; nl.wires.len()] }
    }

    /// Clock one cycle: all registers capture simultaneously, then the
    /// combinational cells settle on `inputs`. Returns this cycle's
    /// output-port values.
    pub fn step(&mut self, inputs: &[i64]) -> Vec<i64> {
        assert_eq!(inputs.len(), self.nl.inputs.len(), "input arity mismatch");
        // Registers: capture every `d` from the previous cycle before
        // any `q` is overwritten (nonblocking-assignment semantics).
        let captured: Vec<i64> =
            self.nl.regs.iter().map(|r| self.values[r.d as usize]).collect();
        for (r, v) in self.nl.regs.iter().zip(captured) {
            self.values[r.q as usize] = v;
        }
        // Combinational settle, each value truncated at its wire width.
        for cell in &self.nl.cells {
            let v = match cell.op {
                CellOp::Input { index } => inputs[index as usize],
                CellOp::Const { value } => value,
                CellOp::AddShift { a, b, shift_a, shift_b, sub } => {
                    let av = self.values[a as usize] << shift_a;
                    let bv = self.values[b as usize] << shift_b;
                    if sub {
                        av.wrapping_sub(bv)
                    } else {
                        av.wrapping_add(bv)
                    }
                }
                CellOp::Neg { a } => self.values[a as usize].wrapping_neg(),
                CellOp::Relu { a } => self.values[a as usize].max(0),
                CellOp::Quant { a, shift, round, clip_min, clip_max } => {
                    quant_scalar(self.values[a as usize], shift, round, clip_min, clip_max)
                }
            };
            self.values[cell.out as usize] = wrap(v, self.nl.wires[cell.out as usize].width);
        }
        self.nl
            .outputs
            .iter()
            .map(|o| {
                let v = self.values[o.wire as usize];
                let v = if o.shift >= 0 { v << o.shift } else { v >> -o.shift };
                wrap(v, o.width)
            })
            .collect()
    }
}

/// Simulate a stream of input vectors at II = 1 (one vector per cycle).
///
/// The stream is flushed with zero vectors so every result drains;
/// outputs are re-aligned by the pipeline latency before returning, so
/// the result is directly comparable with
/// [`crate::dais::interp::evaluate_batch`].
pub fn simulate(nl: &Netlist, stream: &[Vec<i64>]) -> Vec<Vec<i64>> {
    let mut sim = Simulator::new(nl);
    let zero = vec![0i64; nl.inputs.len()];
    let latency = nl.latency as usize;
    let mut out = Vec::with_capacity(stream.len());
    for cycle in 0..stream.len() + latency {
        let inputs = stream.get(cycle).unwrap_or(&zero);
        let vals = sim.step(inputs);
        if cycle >= latency {
            out.push(vals);
        }
    }
    out
}

/// Evaluate a single input vector (pipelined netlists are flushed
/// through their full latency).
pub fn evaluate(nl: &Netlist, inputs: &[i64]) -> Vec<i64> {
    let stream = [inputs.to_vec()];
    simulate(nl, &stream).pop().expect("one output per input vector")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::{interp, DaisBuilder, DaisProgram, NodeId, RoundMode};
    use crate::fixed::QInterval;
    use crate::pipeline::{assign_stages, PipelineConfig};
    use crate::util::Rng;

    fn toy() -> DaisProgram {
        // y0 = (x0 + 2*x1) - x2 ; y1 = 4*(x0 + 2*x1)
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let x0 = b.input(0, q, 0);
        let x1 = b.input(1, q, 0);
        let x2 = b.input(2, q, 0);
        let t = b.add_shift(x0, x1, 1, false);
        let y0 = b.add_shift(t, x2, 0, true);
        b.output(y0, 0);
        b.output(t, 2);
        b.finish()
    }

    #[test]
    fn combinational_netlist_matches_interp() {
        let p = toy();
        let nl = crate::netlist::Netlist::lower(&p, None).unwrap();
        for x in [[3, 5, 7], [-128, 127, -1], [0, 0, 0]] {
            assert_eq!(evaluate(&nl, &x), interp::evaluate(&p, &x));
        }
    }

    #[test]
    fn pipelined_netlist_matches_interp_stream() {
        let p = toy();
        let stages: Vec<u32> = p.nodes.iter().map(|n| n.depth).collect();
        let nl = crate::netlist::Netlist::lower(&p, Some(&stages)).unwrap();
        assert_eq!(nl.latency, 2);
        let stream: Vec<Vec<i64>> = (0..20)
            .map(|i| vec![(i * 7 % 255) - 128, (i * 13 % 255) - 128, (i * 29 % 255) - 128])
            .collect();
        assert_eq!(simulate(&nl, &stream), interp::evaluate_batch(&p, &stream));
    }

    #[test]
    fn wrap_truncates_two_complement() {
        assert_eq!(wrap(255, 8), -1);
        assert_eq!(wrap(127, 8), 127);
        assert_eq!(wrap(-129, 8), 127);
        assert_eq!(wrap(5, 64), 5);
        assert_eq!(wrap(-1, 1), -1);
    }

    /// Random DAIS program exercising every op kind, with bounded value
    /// growth so all intermediates stay far from i64.
    fn random_program(rng: &mut Rng) -> DaisProgram {
        let mut b = DaisBuilder::new();
        let n_in = rng.below(4) + 1;
        let mut pool: Vec<NodeId> = (0..n_in)
            .map(|i| b.input(i, QInterval::new(-128, 127, 0), 0))
            .collect();
        // One even constant (exercises the trailing-zero width path).
        pool.push(b.constant(rng.range_i64(1, 31) * 2));
        pool.push(b.constant(rng.range_i64(-63, 63)));
        let ops = rng.below(24) + 8;
        for _ in 0..ops {
            let a = pool[rng.below(pool.len())];
            let node = match rng.below(8) {
                0 => b.neg(a),
                1 => b.relu(a),
                2 => {
                    let shift = rng.below(4) as i32;
                    let round =
                        if rng.chance(0.5) { RoundMode::Floor } else { RoundMode::HalfUp };
                    let hi = (1i64 << (rng.below(10) + 1)) - 1;
                    b.quant(a, shift, round, -hi - 1, hi)
                }
                _ => {
                    let o = pool[rng.below(pool.len())];
                    b.add_shift(a, o, rng.below(3) as u32, rng.chance(0.5))
                }
            };
            // Cap magnitude growth; wide nodes stay in the program but
            // are never reused (dead cells must also lower and simulate).
            if b.qint(node).width() < 40 {
                pool.push(node);
            }
        }
        for _ in 0..rng.below(3) + 1 {
            let o = pool[rng.below(pool.len())];
            b.output(o, 0);
        }
        b.finish()
    }

    /// The acceptance-criteria differential: for seeded random DAIS
    /// programs × random pipeline configs, the cycle-accurate netlist
    /// simulation matches `dais::interp` bit-exactly on every output
    /// after the reported latency, and both RTL emitters (which walk
    /// this same netlist) materialize identical register counts.
    #[test]
    fn prop_netlist_sim_matches_interp() {
        crate::util::property("netlist_sim_matches_interp", 24, |rng| {
            let p = random_program(rng);
            let stream: Vec<Vec<i64>> = (0..10)
                .map(|_| (0..p.num_inputs).map(|_| rng.range_i64(-128, 127)).collect())
                .collect();
            let want = interp::evaluate_batch(&p, &stream);

            let nl = crate::netlist::Netlist::lower(&p, None).unwrap();
            assert_eq!(simulate(&nl, &stream), want, "combinational netlist diverges");

            let every = rng.below(4) as u32 + 1;
            let stages = assign_stages(&p, &PipelineConfig::every_n_adders(every));
            let nlp = crate::netlist::Netlist::lower(&p, Some(&stages)).unwrap();
            assert_eq!(
                simulate(&nlp, &stream),
                want,
                "pipelined netlist (every {every} adders) diverges"
            );
            // The streaming node-level interpreter agrees too.
            assert_eq!(interp::simulate_pipelined(&p, &stages, &stream), want);

            // Verilog and VHDL walk the same netlist: identical register
            // counts by construction — pin it through the emitted text.
            let v = crate::rtl::emit_verilog(&p, "m", Some(&stages)).unwrap();
            let h = crate::rtl::emit_vhdl(&p, "m", Some(&stages)).unwrap();
            let v_regs =
                v.lines().filter(|l| l.trim_start().starts_with("reg ")).count();
            let h_regs = h
                .lines()
                .filter(|l| l.contains(" <= ") && !l.contains('('))
                .count();
            assert_eq!(v_regs, nlp.regs.len());
            assert_eq!(h_regs, nlp.regs.len());
        });
    }

    /// Same differential over real optimizer output: random CMVM
    /// problems through the full DA pipeline, then netlist-simulated.
    #[test]
    fn prop_netlist_sim_matches_interp_on_cmvm_programs() {
        crate::util::property("netlist_sim_cmvm", 12, |rng| {
            let (d_in, d_out) = (rng.below(4) + 2, rng.below(4) + 2);
            let m: Vec<i64> =
                (0..d_in * d_out).map(|_| rng.range_i64(-127, 127)).collect();
            let prob = crate::cmvm::CmvmProblem::new(d_in, d_out, m, 8).unwrap();
            let opts = crate::cmvm::OptimizeOptions::new(crate::cmvm::Strategy::Da { dc: -1 });
            let sol = crate::cmvm::compile(&prob, &opts).unwrap();
            let every = rng.below(3) as u32 + 1;
            let stages =
                assign_stages(&sol.program, &PipelineConfig::every_n_adders(every));
            let stream: Vec<Vec<i64>> = (0..8)
                .map(|_| (0..d_in).map(|_| rng.range_i64(-128, 127)).collect())
                .collect();
            let want = interp::evaluate_batch(&sol.program, &stream);
            let nl = crate::netlist::Netlist::lower(&sol.program, Some(&stages)).unwrap();
            assert_eq!(simulate(&nl, &stream), want);
        });
    }
}
