//! Netlist — the stage-aware structural hardware IR between [`crate::dais`]
//! and the RTL emitters (paper §5.2).
//!
//! A [`Netlist`] is lowered once from `(DaisProgram, Option<&[u32]> stages)`
//! and makes every hardware decision explicit that the emitters used to
//! take inline while printing text:
//!
//! * **wires** with two's-complement widths derived from the exact
//!   [`QInterval`] of each node — including the trailing-zero exponent
//!   and the extra sign bit a non-negative range needs in a signed
//!   representation (both were dropped by the old string emitters);
//! * **cells** — typed combinational operations whose operands already
//!   point at the correct register tap of their producer's delay line;
//! * **registers** — the materialized pipeline delay lines, one
//!   `q <= d` pair per register, each tagged with the stage it feeds.
//!
//! The stage assignment is validated once here (length, SSA order,
//! monotonicity), so downstream consumers — the [`sim`] cycle-accurate
//! simulator, both RTL emitters in [`crate::rtl`], the [`stats`]
//! per-stage reporter and the [`testbench`] generator — never subtract
//! stages that could underflow. Lowering a malformed program returns a
//! proper error instead of a debug-mode panic.

pub mod sim;
pub mod stats;
pub mod testbench;

use crate::dais::{DaisOp, DaisProgram, RoundMode};
use crate::fixed::QInterval;
use crate::Result;
use anyhow::ensure;

/// Index of a wire inside a [`Netlist`].
pub type WireId = u32;

/// One signed wire (or register output) with an explicit bitwidth.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Wire {
    /// RTL identifier (`n3` for a node value, `n3_r2` for its second
    /// register tap).
    pub name: String,
    /// Two's-complement width in bits (always >= 1).
    pub width: u32,
    /// Driven by a pipeline register (declared `reg` in Verilog,
    /// assigned inside the clocked process in VHDL).
    pub registered: bool,
}

/// The combinational operation of a [`Cell`]. Operand wire ids already
/// reference the correct delay-line tap, so emitters and the simulator
/// need no stage arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellOp {
    /// Drive the wire from input port `in{index}`.
    Input {
        /// External input number.
        index: u32,
    },
    /// Compile-time constant (in the global LSB unit).
    Const {
        /// The constant value.
        value: i64,
    },
    /// `(a << shift_a) ± (b << shift_b)` — one LUT adder/subtractor.
    AddShift {
        /// First operand wire.
        a: WireId,
        /// Second operand wire.
        b: WireId,
        /// Free wiring shift of `a`.
        shift_a: u32,
        /// Free wiring shift of `b`.
        shift_b: u32,
        /// Subtract instead of add.
        sub: bool,
    },
    /// `-a`.
    Neg {
        /// Operand wire.
        a: WireId,
    },
    /// `max(a, 0)` — a mux, no carry chain.
    Relu {
        /// Operand wire.
        a: WireId,
    },
    /// Arithmetic shift right with rounding, then saturation — the NN
    /// requantization node.
    Quant {
        /// Operand wire.
        a: WireId,
        /// Right shift (negative = free left shift).
        shift: i32,
        /// Rounding behaviour.
        round: RoundMode,
        /// Lower clip bound.
        clip_min: i64,
        /// Upper clip bound.
        clip_max: i64,
    },
}

impl CellOp {
    /// Whether this cell consumes a carry chain (the paper's adder
    /// count; mirrors [`DaisOp::is_adder`]).
    pub fn is_adder(&self) -> bool {
        match self {
            CellOp::AddShift { .. } | CellOp::Neg { .. } => true,
            CellOp::Quant { round: RoundMode::HalfUp, shift, .. } => *shift > 0,
            _ => false,
        }
    }
}

/// One combinational cell driving `out`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cell {
    /// The operation.
    pub op: CellOp,
    /// Output wire (always the node-value wire, never a register tap).
    pub out: WireId,
    /// Pipeline stage this cell computes on (0 when combinational).
    pub stage: u32,
}

/// One pipeline register: `q <= d` at every clock edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Register {
    /// Data input wire.
    pub d: WireId,
    /// Registered output wire.
    pub q: WireId,
    /// Stage whose consumers read `q` (registers form the boundary
    /// *into* this stage).
    pub stage: u32,
}

/// An input port `in{index}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InputPort {
    /// External input number.
    pub index: u32,
    /// Port width in bits.
    pub width: u32,
}

/// An output port `out{k}`: a wire read through a free wiring shift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutputPort {
    /// Wire exposed (the correct delay-line tap at the pipeline
    /// latency).
    pub wire: WireId,
    /// Free output wiring shift (negative = exact right shift).
    pub shift: i32,
    /// Port width in bits.
    pub width: u32,
}

/// A lowered, stage-aware hardware netlist. See the module docs for the
/// lowering rules.
#[derive(Debug, Clone)]
pub struct Netlist {
    /// All wires: node values first (wire id == node id), then the
    /// register taps in node order.
    pub wires: Vec<Wire>,
    /// Combinational cells in topological order.
    pub cells: Vec<Cell>,
    /// Pipeline registers (delay lines, flattened).
    pub regs: Vec<Register>,
    /// Input ports, one per external input index.
    pub inputs: Vec<InputPort>,
    /// Output ports in program output order.
    pub outputs: Vec<OutputPort>,
    /// Pipeline latency in cycles (max output stage; 0 when
    /// combinational).
    pub latency: u32,
    /// Whether the design is clocked (a stage assignment was given).
    pub pipelined: bool,
}

/// Signed two's-complement width needed to hold every value of `q` in
/// the global LSB unit: the mantissa width, widened by the trailing-zero
/// exponent (`value = mantissa << exp`) and by one sign bit when the
/// interval never goes negative (a non-negative range `[0, 2^k - 1]`
/// needs `k + 1` signed bits).
fn rtl_width(q: &QInterval) -> u32 {
    if q.is_zero() {
        return 1;
    }
    let body = q.width() as i32 + q.exp;
    body.max(1) as u32 + (!q.signed()) as u32
}

impl Netlist {
    /// Lower a DAIS program (plus an optional stage assignment from
    /// [`crate::pipeline::assign_stages`]) into a netlist.
    ///
    /// Validates once, up front: stage-vector length, SSA operand
    /// order, input indices, non-negative interval exponents, and —
    /// the hardening this pass exists for — stage monotonicity
    /// (`stage[consumer] >= stage[producer]` on every edge). A bad
    /// assignment is a proper error, never an underflow.
    pub fn lower(program: &DaisProgram, stages: Option<&[u32]>) -> Result<Self> {
        let n = program.nodes.len();
        let pipelined = stages.is_some();
        let st: Vec<u32> = match stages {
            Some(st) => {
                ensure!(
                    st.len() == n,
                    "stage assignment covers {} nodes, program has {n}",
                    st.len()
                );
                st.to_vec()
            }
            None => vec![0; n],
        };
        for (i, node) in program.nodes.iter().enumerate() {
            for p in node.op.operands() {
                ensure!(
                    (p as usize) < i,
                    "node {i}: operand {p} does not precede it (SSA violation)"
                );
                ensure!(
                    st[p as usize] <= st[i],
                    "non-monotonic stage assignment: node {i} on stage {} reads \
                     node {p} on stage {}",
                    st[i],
                    st[p as usize]
                );
            }
            if let DaisOp::Input { index } = node.op {
                ensure!(
                    (index as usize) < program.num_inputs,
                    "node {i}: input index {index} >= num_inputs {}",
                    program.num_inputs
                );
            }
            ensure!(
                node.qint.exp >= 0,
                "node {i}: negative interval exponent {} (not an integer unit)",
                node.qint.exp
            );
        }
        for (k, o) in program.outputs.iter().enumerate() {
            ensure!(
                (o.node as usize) < n,
                "output {k}: node {} out of range",
                o.node
            );
        }
        let latency = program
            .outputs
            .iter()
            .map(|o| st[o.node as usize])
            .max()
            .unwrap_or(0);

        // Delay-line length per node: the furthest stage gap any
        // consumer (or the output read-out at `latency`) observes. This
        // is the register computation that used to live inline in
        // `emit_verilog` and had no VHDL counterpart.
        let mut regs_of = vec![0u32; n];
        for (i, node) in program.nodes.iter().enumerate() {
            for p in node.op.operands() {
                let gap = st[i] - st[p as usize];
                regs_of[p as usize] = regs_of[p as usize].max(gap);
            }
        }
        for o in &program.outputs {
            let gap = latency - st[o.node as usize];
            regs_of[o.node as usize] = regs_of[o.node as usize].max(gap);
        }

        // Wires: one per node value (wire id == node id), then the
        // delay-line taps.
        let mut wires: Vec<Wire> = program
            .nodes
            .iter()
            .enumerate()
            .map(|(i, node)| Wire {
                name: format!("n{i}"),
                width: rtl_width(&node.qint),
                registered: false,
            })
            .collect();
        let mut tap: Vec<Vec<WireId>> = (0..n as u32).map(|i| vec![i]).collect();
        let mut regs = Vec::new();
        for i in 0..n {
            let width = wires[i].width;
            for k in 1..=regs_of[i] {
                let q = wires.len() as WireId;
                wires.push(Wire { name: format!("n{i}_r{k}"), width, registered: true });
                regs.push(Register { d: tap[i][(k - 1) as usize], q, stage: st[i] + k });
                tap[i].push(q);
            }
        }

        // Operand reference: producer `p` seen from `consumer_stage` is
        // the tap `consumer_stage - st[p]` registers deep.
        let rd = |p: u32, consumer_stage: u32| -> WireId {
            tap[p as usize][(consumer_stage - st[p as usize]) as usize]
        };

        let mut cells = Vec::with_capacity(n);
        for (i, node) in program.nodes.iter().enumerate() {
            let s = st[i];
            let op = match node.op {
                DaisOp::Input { index } => CellOp::Input { index },
                DaisOp::Const { value } => CellOp::Const { value },
                DaisOp::AddShift { a, b, shift_a, shift_b, sub } => CellOp::AddShift {
                    a: rd(a, s),
                    b: rd(b, s),
                    shift_a,
                    shift_b,
                    sub,
                },
                DaisOp::Neg { a } => CellOp::Neg { a: rd(a, s) },
                DaisOp::Relu { a } => CellOp::Relu { a: rd(a, s) },
                DaisOp::Quant { a, shift, round, clip_min, clip_max } => CellOp::Quant {
                    a: rd(a, s),
                    shift,
                    round,
                    clip_min,
                    clip_max,
                },
            };
            cells.push(Cell { op, out: i as WireId, stage: s });
        }

        let mut inputs: Vec<InputPort> = (0..program.num_inputs)
            .map(|i| InputPort { index: i as u32, width: 1 })
            .collect();
        for node in &program.nodes {
            if let DaisOp::Input { index } = node.op {
                let port = &mut inputs[index as usize];
                port.width = port.width.max(rtl_width(&node.qint));
            }
        }
        let outputs = program
            .outputs
            .iter()
            .map(|o| OutputPort {
                wire: rd(o.node, latency),
                shift: o.shift,
                width: rtl_width(&program.nodes[o.node as usize].qint.shl(o.shift)),
            })
            .collect();

        Ok(Self { wires, cells, regs, inputs, outputs, latency, pipelined })
    }

    /// Wire metadata accessor.
    pub fn wire(&self, id: WireId) -> &Wire {
        &self.wires[id as usize]
    }

    /// Cells that consume a carry chain (the paper's adder count).
    pub fn adder_count(&self) -> usize {
        self.cells.iter().filter(|c| c.op.is_adder()).count()
    }

    /// Total pipeline register bits (the flip-flop count of the emitted
    /// design; `estimate::pipelined` additionally charges one output
    /// boundary layer, per the paper's reporting convention).
    pub fn reg_bits(&self) -> u64 {
        self.regs.iter().map(|r| self.wires[r.q as usize].width as u64).sum()
    }

    /// Register bits clocked into each stage boundary, indexed by stage
    /// (`[0]` is always 0: stage 0 reads the raw inputs).
    pub fn reg_bits_per_stage(&self) -> Vec<u64> {
        let n_stages = self
            .regs
            .iter()
            .map(|r| r.stage + 1)
            .max()
            .unwrap_or(0)
            .max(self.latency + 1);
        let mut out = vec![0u64; n_stages as usize];
        for r in &self.regs {
            out[r.stage as usize] += self.wires[r.q as usize].width as u64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::DaisBuilder;
    use crate::pipeline::{assign_stages, PipelineConfig};

    fn q8() -> QInterval {
        QInterval::new(-128, 127, 0)
    }

    /// x, y -> relu((x + 2y) - x), the emitter test program.
    fn toy() -> DaisProgram {
        let mut b = DaisBuilder::new();
        let x = b.input(0, q8(), 0);
        let y = b.input(1, q8(), 0);
        let t = b.add_shift(x, y, 1, false);
        let u = b.add_shift(t, x, 0, true);
        let r = b.relu(u);
        b.output(r, 0);
        b.finish()
    }

    #[test]
    fn combinational_lowering_has_no_registers() {
        let p = toy();
        let nl = Netlist::lower(&p, None).unwrap();
        assert!(!nl.pipelined);
        assert_eq!(nl.latency, 0);
        assert!(nl.regs.is_empty());
        assert_eq!(nl.cells.len(), p.nodes.len());
        assert_eq!(nl.wires.len(), p.nodes.len());
        assert_eq!(nl.adder_count(), p.adder_count());
        assert_eq!(nl.inputs.len(), 2);
        assert_eq!(nl.outputs.len(), 1);
        assert_eq!(nl.reg_bits(), 0);
    }

    #[test]
    fn pipelined_lowering_materializes_delay_lines() {
        let p = toy();
        // Manual stages = adder depths: n0,n1 on 0; n2 on 1; n3,n4 on 2.
        let stages: Vec<u32> = p.nodes.iter().map(|n| n.depth).collect();
        let nl = Netlist::lower(&p, Some(&stages)).unwrap();
        assert!(nl.pipelined);
        assert_eq!(nl.latency, 2);
        // n0 is read at stage 2 (by n3): 2 regs; n1 at stage 1: 1 reg;
        // n2 at stage 2: 1 reg. n3/n4 are consumed in-stage.
        assert_eq!(nl.regs.len(), 4);
        let names: Vec<&str> =
            nl.regs.iter().map(|r| nl.wire(r.q).name.as_str()).collect();
        assert_eq!(names, vec!["n0_r1", "n0_r2", "n1_r1", "n2_r1"]);
        assert!(nl.regs.iter().all(|r| nl.wire(r.q).registered));
        // Stage tags: n0_r1 feeds stage 1, n0_r2 stage 2, etc.
        let tags: Vec<u32> = nl.regs.iter().map(|r| r.stage).collect();
        assert_eq!(tags, vec![1, 2, 1, 2]);
        // 8 + 8 + 8 + 10 register bits.
        assert_eq!(nl.reg_bits(), 34);
        assert_eq!(nl.reg_bits_per_stage(), vec![0, 16, 18]);
    }

    #[test]
    fn operands_resolve_to_register_taps() {
        let p = toy();
        let stages: Vec<u32> = p.nodes.iter().map(|n| n.depth).collect();
        let nl = Netlist::lower(&p, Some(&stages)).unwrap();
        // n3 = (n2 via 1 reg) - (n0 via 2 regs).
        let CellOp::AddShift { a, b, .. } = nl.cells[3].op else {
            panic!("node 3 is an add")
        };
        assert_eq!(nl.wire(a).name, "n2_r1");
        assert_eq!(nl.wire(b).name, "n0_r2");
        // The output reads n4 directly (stage 2 == latency).
        assert_eq!(nl.wire(nl.outputs[0].wire).name, "n4");
    }

    #[test]
    fn non_monotonic_stages_are_an_error_not_a_panic() {
        let p = toy();
        // n2 (reads n0, n1) on an *earlier* stage than its operands.
        let bad = vec![1, 1, 0, 1, 1];
        let err = Netlist::lower(&p, Some(&bad)).unwrap_err();
        assert!(err.to_string().contains("non-monotonic"), "got: {err}");
    }

    #[test]
    fn wrong_stage_length_is_an_error() {
        let p = toy();
        let err = Netlist::lower(&p, Some(&[0, 0])).unwrap_err();
        assert!(err.to_string().contains("covers 2 nodes"), "got: {err}");
    }

    #[test]
    fn width_rule_unsigned_ranges_get_a_sign_bit() {
        // [0, 255] needs 9 signed bits, not 8 (the old emitters dropped
        // this bit and the sign of 255 flipped in simulation).
        assert_eq!(rtl_width(&QInterval::new(0, 255, 0)), 9);
        assert_eq!(rtl_width(&QInterval::new(-128, 127, 0)), 8);
        assert_eq!(rtl_width(&QInterval::new(0, 0, 0)), 1);
        // Trailing-zero exponents widen the wire: mantissa 1 at exp 2 is
        // the value 4 -> 3 magnitude bits + sign.
        assert_eq!(rtl_width(&QInterval::new(1, 1, 2)), 4);
        assert_eq!(rtl_width(&QInterval::new(-3, -3, 1)), 4);
    }

    #[test]
    fn relu_and_const_wires_are_wide_enough() {
        let mut b = DaisBuilder::new();
        let x = b.input(0, q8(), 0);
        let r = b.relu(x); // [0, 127] -> 8 signed bits
        let c = b.constant(4); // mantissa 1 @ exp 2 -> 4 bits
        let t = b.add_shift(r, c, 0, false);
        b.output(t, 0);
        let p = b.finish();
        let nl = Netlist::lower(&p, None).unwrap();
        assert_eq!(nl.wire(1).width, 8);
        assert_eq!(nl.wire(2).width, 4);
        // [4, 131] -> 8 magnitude bits + sign.
        assert_eq!(nl.wire(3).width, 9);
    }

    #[test]
    fn assign_stages_output_always_lowers() {
        let p = toy();
        for every in [1, 2, 5] {
            let stages = assign_stages(&p, &PipelineConfig::every_n_adders(every));
            Netlist::lower(&p, Some(&stages)).expect("assign_stages is monotone");
        }
    }
}
