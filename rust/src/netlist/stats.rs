//! Per-stage netlist reporting, rendered as a paper-style table.
//!
//! Combines the analytic per-stage resource model
//! ([`crate::estimate::per_stage`]) with the lowered netlist's
//! materialized register delay lines, giving the pipelining loop the
//! data the greedy stage assigner never sees: where the LUTs sit, which
//! stage sets the clock, and how many register bits each stage boundary
//! really costs in the emitted design.

use super::Netlist;
use crate::dais::DaisProgram;
use crate::estimate::{per_stage, FpgaModel};
use crate::report::Table;

/// Render the per-stage resource/register table for a pipelined
/// program: one row per stage plus a TOTAL row. The `reg bits in`
/// column counts the register bits clocked into each stage's boundary
/// (stage 0 reads the raw inputs, so its row is always 0).
///
/// `nl` must be the lowering of `(program, Some(stages))` — callers
/// that already emitted RTL or simulated have it in hand; lowering is
/// not repeated here.
pub fn stage_table(
    nl: &Netlist,
    program: &DaisProgram,
    stages: &[u32],
    model: &FpgaModel,
) -> Table {
    let est = per_stage(program, stages, model);
    let reg_bits = nl.reg_bits_per_stage();
    let mut t = Table::new(
        "Per-stage netlist resources",
        &["stage", "cells", "adders", "LUT", "crit[ns]", "reg bits in"],
    );
    for r in &est {
        let bits = reg_bits.get(r.stage as usize).copied().unwrap_or(0);
        t.push(vec![
            r.stage.to_string(),
            r.cells.to_string(),
            r.adders.to_string(),
            r.lut.to_string(),
            format!("{:.2}", r.crit_ns),
            bits.to_string(),
        ]);
    }
    t.push(vec![
        "TOTAL".into(),
        est.iter().map(|r| r.cells).sum::<u64>().to_string(),
        est.iter().map(|r| r.adders).sum::<u64>().to_string(),
        est.iter().map(|r| r.lut).sum::<u64>().to_string(),
        format!("{:.2}", est.iter().map(|r| r.crit_ns).fold(0.0, f64::max)),
        nl.reg_bits().to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::DaisBuilder;
    use crate::fixed::QInterval;

    #[test]
    fn stage_table_renders_all_stages() {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let x = b.input(0, q, 0);
        let y = b.input(1, q, 0);
        let t = b.add_shift(x, y, 1, false);
        let u = b.add_shift(t, x, 0, true);
        b.output(u, 0);
        let p = b.finish();
        let stages: Vec<u32> = p.nodes.iter().map(|n| n.depth).collect();
        let nl = Netlist::lower(&p, Some(&stages)).unwrap();
        let table = stage_table(&nl, &p, &stages, &FpgaModel::default());
        let s = table.render();
        assert!(s.contains("Per-stage netlist resources"));
        assert!(s.contains("reg bits in"));
        assert!(s.contains("TOTAL"));
        // Three stages (0, 1, 2) plus header, separator and total.
        assert_eq!(s.lines().filter(|l| l.starts_with('|')).count(), 6);
    }
}
