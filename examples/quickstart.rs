//! Quickstart: optimize one CMVM with da4ml and inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a random 16×16 8-bit constant matrix, optimizes it with the
//! two-stage da4ml algorithm under three delay constraints, verifies the
//! adder graph is *exactly* equivalent to the matrix (symbolically and
//! numerically), and prints the paper-style summary against the naive
//! distributed-arithmetic and latency-strategy baselines.

use da4ml::baseline::mac::{mac_report, DspPolicy};
use da4ml::cmvm::{compile, CmvmProblem, OptimizeOptions, Strategy};
use da4ml::dais::{interp, verify};
use da4ml::estimate::{combinational, FpgaModel};
use da4ml::report::Table;
use da4ml::util::Rng;

fn main() {
    let (d_in, d_out, bits) = (16, 16, 8);
    let mut rng = Rng::seed_from(42);
    let lo = (1i64 << (bits - 1)) + 1;
    let hi = (1i64 << bits) - 1;
    let matrix: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(lo, hi)).collect();
    let problem = CmvmProblem::new(d_in, d_out, matrix, 8).expect("valid bits");
    let model = FpgaModel::default();

    println!(
        "CMVM problem: {d_in}x{d_out}, {bits}-bit weights, {} CSD digits\n",
        problem.csd_nnz()
    );

    let mut table = Table::new(
        "Strategies",
        &["strategy", "dc", "adders", "depth", "LUT", "DSP", "latency[ns]", "opt[ms]"],
    );

    // Latency baseline (hls4ml MAC loop, analytic model).
    let macr = mac_report(&problem, &model, &DspPolicy::default());
    table.push(vec![
        "latency".into(),
        "-".into(),
        format!("({})", macr.adders),
        macr.depth.to_string(),
        macr.lut.to_string(),
        macr.dsp.to_string(),
        format!("{:.2}", macr.latency_ns),
        "-".into(),
    ]);

    for (strategy, dc) in [
        (Strategy::NaiveDa, "-"),
        (Strategy::Da { dc: 0 }, "0"),
        (Strategy::Da { dc: 2 }, "2"),
        (Strategy::Da { dc: -1 }, "-1"),
    ] {
        let sol = compile(&problem, &OptimizeOptions::new(strategy)).expect("compile");
        // Exactness: the whole point of non-approximate DA.
        verify::check_well_formed(&sol.program).expect("well-formed");
        verify::check_cmvm_equivalence(&sol.program, &problem.matrix, d_in, d_out)
            .expect("bit-exact");
        let x: Vec<i64> = (0..d_in as i64).map(|j| (j * 37 % 255) - 128).collect();
        let got = interp::evaluate_checked(&sol.program, &x);
        let want = problem.reference(&x);
        assert!(got.iter().zip(&want).all(|(g, w)| *g as i128 == *w));

        let rep = combinational(&sol.program, &model);
        table.push(vec![
            strategy.name().into(),
            dc.into(),
            sol.adders.to_string(),
            sol.depth.to_string(),
            rep.lut.to_string(),
            "0".into(),
            format!("{:.2}", rep.latency_ns),
            format!("{:.2}", sol.opt_time.as_secs_f64() * 1e3),
        ]);
    }
    println!("{}", table.render());
    println!("All adder graphs verified bit-exact against x^T M.");
}
