//! Standalone RTL generation (paper §5.2 / §6.3): fuse the jet-tagging
//! network, pipeline it, and emit synthesizable Verilog and VHDL —
//! bypassing the HLS flow entirely.
//!
//! ```bash
//! make artifacts && cargo run --release --example rtl_flow
//! ```

use anyhow::Result;
use da4ml::cmvm::Strategy;
use da4ml::dais::interp;
use da4ml::estimate::{pipelined, FpgaModel};
use da4ml::nn::{self, NetworkSpec, TestVectors};
use da4ml::pipeline::{assign_stages, latency, PipelineConfig};
use da4ml::rtl::{emit_verilog, emit_vhdl};
use da4ml::runtime;

fn main() -> Result<()> {
    let dir = runtime::artifacts_dir();
    let spec = NetworkSpec::from_json(&runtime::load_text(dir.join("jet_mlp.weights.json"))?)?;
    let vecs = TestVectors::from_json(&runtime::load_text(dir.join("jet_mlp.testvec.json"))?)?;
    let prog = nn::compile::fuse(&spec, Strategy::Da { dc: 2 })?;
    let model = FpgaModel::default();

    // The paper's two pipelining settings.
    for (name, every) in [("200 MHz (every 5 adders)", 5u32), ("1 GHz (every adder)", 1u32)] {
        let stages = assign_stages(&prog, &PipelineConfig::every_n_adders(every));
        let rep = pipelined(&prog, &stages, &model);
        println!(
            "{name}: latency {} cycles, LUT {}, FF {}, est Fmax {:.0} MHz",
            latency(&prog, &stages) + 1,
            rep.lut,
            rep.ff,
            rep.fmax_mhz
        );
        // Cycle-accurate verification of the registered design.
        let stream: Vec<Vec<i64>> = vecs.inputs.iter().take(32).cloned().collect();
        assert_eq!(
            interp::simulate_pipelined(&prog, &stages, &stream),
            interp::evaluate_batch(&prog, &stream),
            "pipelined design must be bit-and-cycle exact"
        );
    }

    let stages = assign_stages(&prog, &PipelineConfig::every_n_adders(5));
    let v = emit_verilog(&prog, "jet_mlp", Some(&stages));
    let vhdl = emit_vhdl(&prog, "jet_mlp");
    std::fs::create_dir_all("target/rtl")?;
    std::fs::write("target/rtl/jet_mlp.v", &v)?;
    std::fs::write("target/rtl/jet_mlp.vhd", &vhdl)?;
    println!(
        "wrote target/rtl/jet_mlp.v ({} lines) and .vhd ({} lines)",
        v.lines().count(),
        vhdl.lines().count()
    );
    Ok(())
}
