//! Standalone RTL generation (paper §5.2 / §6.3): fuse the jet-tagging
//! network, pipeline it, lower the stage-aware netlist, and emit
//! synthesizable Verilog and VHDL — bypassing the HLS flow entirely.
//! Both backends walk the same netlist, so the VHDL is pipelined with
//! the identical register delay lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example rtl_flow
//! ```

use anyhow::Result;
use da4ml::cmvm::Strategy;
use da4ml::dais::interp;
use da4ml::estimate::{pipelined, FpgaModel};
use da4ml::netlist::{sim, stats, testbench, Netlist};
use da4ml::nn::{self, NetworkSpec, TestVectors};
use da4ml::pipeline::{assign_stages, latency, PipelineConfig};
use da4ml::rtl::{verilog_from_netlist, vhdl_from_netlist};
use da4ml::runtime;

fn main() -> Result<()> {
    let dir = runtime::artifacts_dir();
    let spec = NetworkSpec::from_json(&runtime::load_text(dir.join("jet_mlp.weights.json"))?)?;
    let vecs = TestVectors::from_json(&runtime::load_text(dir.join("jet_mlp.testvec.json"))?)?;
    let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: 2 });
    let prog = nn::compile::compile(&spec, &opts)?.program;
    let model = FpgaModel::default();

    // The paper's two pipelining settings.
    for (name, every) in [("200 MHz (every 5 adders)", 5u32), ("1 GHz (every adder)", 1u32)] {
        let stages = assign_stages(&prog, &PipelineConfig::every_n_adders(every));
        let rep = pipelined(&prog, &stages, &model);
        let nl = Netlist::lower(&prog, Some(&stages))?;
        println!(
            "{name}: latency {} cycles, LUT {}, FF {}, est Fmax {:.0} MHz, \
             {} register bits materialized",
            latency(&prog, &stages) + 1,
            rep.lut,
            rep.ff,
            rep.fmax_mhz,
            nl.reg_bits()
        );
        // Cycle-accurate verification of the registered design — through
        // the netlist simulator, which also models every wire width.
        let stream: Vec<Vec<i64>> = vecs.inputs.iter().take(32).cloned().collect();
        assert_eq!(
            sim::simulate(&nl, &stream),
            interp::evaluate_batch(&prog, &stream),
            "pipelined netlist must be bit-and-cycle exact"
        );
    }

    // Lower the 200 MHz configuration once; table, both RTL backends
    // and the testbench all walk the same netlist.
    let stages = assign_stages(&prog, &PipelineConfig::every_n_adders(5));
    let nl = Netlist::lower(&prog, Some(&stages))?;
    println!("{}", stats::stage_table(&nl, &prog, &stages, &model).render());

    let v = verilog_from_netlist(&nl, "jet_mlp");
    let vhdl = vhdl_from_netlist(&nl, "jet_mlp");
    let tb = testbench::emit_testbench(&nl, "jet_mlp", &vecs, 32)?;
    std::fs::create_dir_all("target/rtl")?;
    std::fs::write("target/rtl/jet_mlp.v", &v)?;
    std::fs::write("target/rtl/jet_mlp.vhd", &vhdl)?;
    std::fs::write("target/rtl/jet_mlp_tb.v", &tb)?;
    println!(
        "wrote target/rtl/jet_mlp.v ({} lines), .vhd ({} lines, pipelined) and \
         jet_mlp_tb.v ({} lines, self-checking)",
        v.lines().count(),
        vhdl.lines().count(),
        tb.lines().count()
    );
    Ok(())
}
