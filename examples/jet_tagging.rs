//! END-TO-END driver: the full three-layer stack on the high-level-
//! feature jet tagging network (paper §6.2.1).
//!
//! ```bash
//! make artifacts && cargo run --release --example jet_tagging
//! ```
//!
//! Proves all layers compose:
//!  1. loads the build-time artifacts (weights + test vectors + the
//!     JAX/Pallas-lowered HLO golden model);
//!  2. executes the golden model (PJRT with `--features pjrt`, the
//!     pure-Rust `runtime::golden` backend by default — no Python
//!     anywhere on either path);
//!  3. compiles the network to a fully-unrolled DAIS adder graph with
//!     the da4ml strategy;
//!  4. checks golden output == DAIS simulation == host integer
//!     simulation **bit-exactly** on every test vector;
//!  5. sweeps all six quantization levels and reports the paper-style
//!     accuracy/resource table for latency vs DA strategies.

use anyhow::Result;
use da4ml::cmvm::Strategy;
use da4ml::dais::interp;
use da4ml::estimate::FpgaModel;
use da4ml::nn::{self, NetworkSpec, TestVectors};
use da4ml::pipeline::{assign_stages, PipelineConfig};
use da4ml::report::Table;
use da4ml::runtime::{self, TensorI32};
use std::path::Path;

/// Golden outputs for every input vector: PJRT-executed HLO when built
/// with `--features pjrt`, otherwise the pure-Rust golden backend.
fn golden_outputs(
    spec: &NetworkSpec,
    dir: &Path,
    inputs: &[Vec<i64>],
) -> Result<Vec<Vec<i64>>> {
    #[cfg(feature = "pjrt")]
    {
        let rt = runtime::Runtime::cpu()?;
        let golden = rt.load_hlo_text(dir.join("jet_mlp.hlo.txt"))?;
        println!("golden backend: PJRT ({})", rt.platform());
        let weights = nn::weight_tensors(spec);
        inputs
            .iter()
            .map(|x| {
                let mut args = vec![TensorI32::new(
                    x.iter().map(|&v| v as i32).collect(),
                    vec![x.len() as i64],
                )];
                args.extend(weights.iter().cloned());
                let out = golden.run_i32(&args)?;
                Ok(out[0].data.iter().map(|&v| v as i64).collect())
            })
            .collect()
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = dir;
        let golden = runtime::golden::GoldenModel::from_spec(spec.clone());
        println!("golden backend: pure-Rust (rebuild with --features pjrt for PJRT)");
        inputs
            .iter()
            .map(|x| {
                let args = [TensorI32::new(
                    x.iter().map(|&v| v as i32).collect(),
                    vec![x.len() as i64],
                )];
                let out = golden.run_i32(&args)?;
                Ok(out[0].data.iter().map(|&v| v as i64).collect())
            })
            .collect()
    }
}

fn main() -> Result<()> {
    let dir = runtime::artifacts_dir();
    let spec = NetworkSpec::from_json(&runtime::load_text(dir.join("jet_mlp.weights.json"))?)?;
    let vecs = TestVectors::from_json(&runtime::load_text(dir.join("jet_mlp.testvec.json"))?)?;

    // --- Golden model (PJRT or pure-Rust fallback) -----------------------
    let golden = golden_outputs(&spec, &dir, &vecs.inputs)?;

    // --- da4ml compilation ----------------------------------------------
    let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: 2 });
    let program = nn::compile::compile(&spec, &opts)?.program;
    println!(
        "fused DAIS program: {} nodes, {} adders, depth {}",
        program.nodes.len(),
        program.adder_count(),
        program.adder_depth()
    );

    // --- Bit-exact cross-check against the *exported* outputs -----------
    // The JAX-side export (vecs.outputs) is the independent reference:
    // golden backend, DAIS graph, and host simulation must all reproduce
    // it exactly. (Without the pjrt feature the golden backend shares
    // nn::sim with the host leg, so the exported vectors are what keeps
    // this check non-circular.)
    let n = vecs.inputs.len();
    assert_eq!(vecs.outputs.len(), n, "testvec outputs/inputs arity");
    let mut all_match = true;
    for ((x, want), gold) in vecs.inputs.iter().zip(&vecs.outputs).zip(&golden) {
        let dais = interp::evaluate_checked(&program, x);
        let host = nn::sim::forward(&spec, x);
        if gold != want || &dais != want || &host != want {
            all_match = false;
            eprintln!(
                "MISMATCH on input {x:?}:\n want={want:?}\n gold={gold:?}\n \
                 dais={dais:?}\n host={host:?}"
            );
            break;
        }
    }
    println!("export == golden == DAIS == host-sim on {n}/{n} test vectors: {all_match}");
    assert!(all_match, "golden cross-check failed");

    // --- Streaming II=1 check (cycle-accurate pipeline) ------------------
    let stages = assign_stages(&program, &PipelineConfig::every_n_adders(5));
    let stream: Vec<Vec<i64>> = vecs.inputs.iter().take(64).cloned().collect();
    let piped = interp::simulate_pipelined(&program, &stages, &stream);
    let comb = interp::evaluate_batch(&program, &stream);
    assert_eq!(piped, comb, "pipelined streaming at II=1 must match");
    println!(
        "pipelined (every 5 adders): latency {} cycles, II=1 verified on {} vectors",
        da4ml::pipeline::latency(&program, &stages) + 1,
        stream.len()
    );

    // --- Quantization sweep (paper Table 5 shape) ------------------------
    let model = FpgaModel::default();
    let cfg = PipelineConfig::every_n_adders(5);
    let mut table = Table::new(
        "Jet tagging @200 MHz (paper Table 5 shape)",
        &["level", "strategy", "acc", "LUT", "DSP", "FF", "adders", "cycles"],
    );
    let metrics = runtime::load_json_value(dir.join("metrics.json"))?;
    for (w, a) in [(8, 8), (7, 7), (6, 6), (5, 6), (4, 6), (4, 5)] {
        let tag = format!("jet_mlp_w{w}a{a}");
        let lspec =
            NetworkSpec::from_json(&runtime::load_text(dir.join(format!("{tag}.weights.json")))?)?;
        let acc = metrics
            .get("jet_mlp")?
            .get(&format!("w{w}a{a}"))?
            .get("accuracy")?
            .as_f64()?;
        for s in [Strategy::Latency, Strategy::Da { dc: 2 }] {
            let agg = nn::compile::network_report(&lspec, s, &model, &cfg)?;
            table.push(vec![
                format!("w{w}a{a}"),
                s.name().into(),
                format!("{:.3}", acc),
                agg.lut.to_string(),
                agg.dsp.to_string(),
                agg.ff.to_string(),
                agg.adders.to_string(),
                agg.latency_cycles.to_string(),
            ]);
        }
    }
    println!("{}", table.render());
    println!("end-to-end OK");
    Ok(())
}
