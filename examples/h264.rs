//! The paper's worked example (Fig. 3 / Fig. 4): the H.264 4×4 integer
//! transform optimizes from 12 adders (naive DA) to 8 adders.
//!
//! ```bash
//! cargo run --release --example h264
//! ```

use da4ml::cmvm::{compile, CmvmProblem, OptimizeOptions, Strategy};
use da4ml::dais::{interp, verify, DaisOp};
use da4ml::rtl::emit_verilog;

fn main() {
    // Paper's matrix (Fig. 3) computes y = M x with rows
    // [1 1 1 1; 2 1 -1 -2; 1 -1 -1 1; 1 -2 2 -1]; our convention is
    // y^T = x^T M, so our column i is the paper's row i.
    let m = vec![
        1, 2, 1, 1, //
        1, 1, -1, -2, //
        1, -1, -1, 2, //
        1, -2, 1, -1, //
    ];
    let problem = CmvmProblem::new(4, 4, m.clone(), 8).expect("valid bits");

    let naive = compile(&problem, &OptimizeOptions::new(Strategy::NaiveDa)).expect("compile");
    let da = compile(&problem, &OptimizeOptions::new(Strategy::Da { dc: -1 })).expect("compile");
    verify::check_cmvm_equivalence(&da.program, &m, 4, 4).unwrap();

    println!("H.264 integer transform (paper Fig. 3/4):");
    println!("  naive DA : {} adders", naive.adders);
    println!("  da4ml    : {} adders (paper: 12 -> 8)", da.adders);
    assert_eq!(naive.adders, 12);
    assert_eq!(da.adders, 8);

    println!("\nAdder graph:");
    for (id, node) in da.program.iter() {
        if let DaisOp::AddShift { a, b, shift_a, shift_b, sub } = node.op {
            let op = if sub { "-" } else { "+" };
            println!(
                "  n{id} = (n{a} << {shift_a}) {op} (n{b} << {shift_b})   \
                 [depth {}, range {}..{}]",
                node.depth, node.qint.min, node.qint.max
            );
        }
    }

    // Spot-check against the transform of a sample block row.
    let x = vec![5, -3, 12, 7];
    let y = interp::evaluate_checked(&da.program, &x);
    println!("\nx = {x:?}  ->  y = {y:?}");
    assert_eq!(y[0], 5 - 3 + 12 + 7);
    assert_eq!(y[1], 2 * 5 - 3 - 12 - 2 * 7);

    let verilog = emit_verilog(&da.program, "h264_transform", None).expect("emit verilog");
    println!("\nGenerated Verilog ({} lines):", verilog.lines().count());
    for line in verilog.lines().take(12) {
        println!("  {line}");
    }
    println!("  ...");
}
