//! Convolutional flow (paper §6.2.2): the SVHN-like LeNet network uses
//! the HLS-flow path — conv CMVM kernels are optimized once and
//! time-multiplexed over image positions, so the network is simulated
//! layer-by-layer and resources are reported per kernel instance.
//!
//! ```bash
//! make artifacts && cargo run --release --example svhn_conv
//! ```

use anyhow::Result;
use da4ml::cmvm::Strategy;
use da4ml::estimate::FpgaModel;
use da4ml::nn::{self, NetworkSpec, TestVectors};
use da4ml::pipeline::PipelineConfig;
use da4ml::report::Table;
use da4ml::runtime;

fn main() -> Result<()> {
    let dir = runtime::artifacts_dir();
    let spec = NetworkSpec::from_json(&runtime::load_text(dir.join("svhn.weights.json"))?)?;
    let vecs = TestVectors::from_json(&runtime::load_text(dir.join("svhn.testvec.json"))?)?;

    // Bit-exact layered simulation vs the exported JAX golden outputs.
    let outs = nn::sim::forward_batch(&spec, &vecs.inputs);
    let exact = outs.iter().zip(&vecs.outputs).filter(|(a, b)| a == b).count();
    println!("{}/{} outputs bit-exact vs JAX/Pallas export", exact, outs.len());
    assert_eq!(exact, outs.len());
    if !vecs.labels.is_empty() {
        println!("accuracy on test vectors: {:.3}", nn::sim::accuracy(&outs, &vecs.labels));
    }

    let model = FpgaModel::default();
    let cfg = PipelineConfig::every_n_adders(5);
    let mut table = Table::new(
        "SVHN-like conv net, per-layer CMVM (paper Table 7 shape)",
        &["layer", "strategy", "inst", "LUT", "DSP", "FF", "adders"],
    );
    for s in [Strategy::Latency, Strategy::Da { dc: 2 }] {
        let reports = nn::compile::layer_reports(&spec, s, &model, &cfg)?;
        for r in &reports {
            table.push(vec![
                r.name.clone(),
                s.name().into(),
                r.instances.to_string(),
                r.total.lut.to_string(),
                r.total.dsp.to_string(),
                r.total.ff.to_string(),
                r.total.adders.to_string(),
            ]);
        }
        let agg = nn::compile::aggregate(&reports);
        table.push(vec![
            "TOTAL".into(),
            s.name().into(),
            "-".into(),
            agg.lut.to_string(),
            agg.dsp.to_string(),
            agg.ff.to_string(),
            agg.adders.to_string(),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
