//! In-tree mutation fuzzer for the da4ml wire decoders.
//!
//! The workspace is hermetic (no registry access), so the usual
//! `cargo-fuzz`/libFuzzer pairing is unavailable. This crate keeps the
//! cargo-fuzz *layout* — one binary per target under `fuzz_targets/`,
//! a seed corpus under `corpus/<target>/` — but drives the targets
//! with a small deterministic mutation engine built on
//! [`da4ml::util::Rng`]. Every corpus seed runs unmutated first, then
//! `--runs` mutated inputs are derived from it; a property violation
//! is a plain `panic!`, so a failing input aborts the process after
//! printing the run seed that reproduces it.
//!
//! ```text
//! cargo run -p da4ml-fuzz --bin fuzz_json_pull -- --runs 4096
//! cargo run -p da4ml-fuzz --bin fuzz_serve_wire -- --runs 4096 --seed 7
//! ```

use da4ml::util::Rng;
use std::fs;
use std::path::PathBuf;

/// Command-line options shared by every fuzz target.
#[derive(Debug, Clone)]
pub struct Options {
    /// Mutated inputs to run after the unmutated corpus pass.
    pub runs: u64,
    /// Base seed; each run derives its own RNG stream from it.
    pub seed: u64,
    /// Mutated inputs are clamped to this many bytes.
    pub max_len: usize,
}

impl Default for Options {
    fn default() -> Self {
        Self {
            runs: 1024,
            seed: 0xda4b_a5e,
            max_len: 4096,
        }
    }
}

impl Options {
    /// Parse `--runs N`, `--seed N` and `--max-len N` from the process
    /// arguments. Unknown flags abort with a usage message so a typo
    /// cannot silently shrink a CI fuzz budget.
    pub fn from_args() -> Self {
        let mut opts = Self::default();
        let mut args = std::env::args().skip(1);
        while let Some(flag) = args.next() {
            match flag.as_str() {
                "--runs" => opts.runs = arg_u64(&mut args, "--runs"),
                "--seed" => opts.seed = arg_u64(&mut args, "--seed"),
                "--max-len" => opts.max_len = arg_u64(&mut args, "--max-len") as usize,
                other => panic!("unknown flag {other:?} (want --runs, --seed, --max-len)"),
            }
        }
        opts
    }
}

fn arg_u64(args: &mut impl Iterator<Item = String>, flag: &str) -> u64 {
    let text = args.next().unwrap_or_else(|| panic!("{flag} requires a value"));
    text.parse()
        .unwrap_or_else(|e| panic!("{flag}: invalid number {text:?}: {e}"))
}

/// Load the seed corpus for `target`: every non-empty line of every
/// file under `corpus/<target>/` (sorted by file name) is one input,
/// so a single `seeds.jsonl` and one-file-per-seed layouts both work.
/// Falls back to `{}` when the directory is missing or empty so a
/// target never fuzzes from nothing.
pub fn load_corpus(target: &str) -> Vec<Vec<u8>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("corpus")
        .join(target);
    let mut files: Vec<PathBuf> = match fs::read_dir(&dir) {
        Ok(entries) => entries
            .filter_map(Result::ok)
            .map(|e| e.path())
            .filter(|p| p.is_file())
            .collect(),
        Err(_) => Vec::new(),
    };
    files.sort();
    let mut corpus = Vec::new();
    for path in files {
        let Ok(bytes) = fs::read(&path) else { continue };
        for line in bytes.split(|&b| b == b'\n') {
            let line = trim_ascii(line);
            if !line.is_empty() {
                corpus.push(line.to_vec());
            }
        }
    }
    if corpus.is_empty() {
        corpus.push(b"{}".to_vec());
    }
    corpus
}

fn trim_ascii(mut bytes: &[u8]) -> &[u8] {
    while let [first, rest @ ..] = bytes {
        if first.is_ascii_whitespace() {
            bytes = rest;
        } else {
            break;
        }
    }
    while let [rest @ .., last] = bytes {
        if last.is_ascii_whitespace() {
            bytes = rest;
        } else {
            break;
        }
    }
    bytes
}

/// Structure-aware dictionary: wire keywords and boundary literals the
/// byte-level mutations would take a long time to stumble into
/// (`-0.0` and `1e300` exercise the serializer's float edge cases,
/// the quoted keys steer mutants toward deep decoder states).
const TOKENS: &[&[u8]] = &[
    b"{",
    b"}",
    b"[",
    b"]",
    b":",
    b",",
    b"\"",
    b"\\",
    b"null",
    b"true",
    b"false",
    b"-0.0",
    b"1e300",
    b"-9223372036854775808",
    b"9223372036854775807",
    b"\\u0041",
    b"\\ud834",
    b"\"type\"",
    b"\"explore\"",
    b"\"shutdown\"",
    b"\"stats\"",
    b"\"id\"",
    b"\"matrix\"",
    b"\"bits\"",
    b"\"strategy\"",
    b"\"dc\"",
    b"\"emit\"",
    b"\"objective\"",
    b"\"verilog\"",
    b"\"timing\"",
];

/// Derive one mutated input: clone a random corpus seed, apply 1..=8
/// random mutations (bit flips, byte edits, span duplication, splices
/// from other seeds, truncation, dictionary-token insertion), clamp to
/// `max_len`.
pub fn mutate(corpus: &[Vec<u8>], rng: &mut Rng, max_len: usize) -> Vec<u8> {
    let mut buf = corpus[rng.below(corpus.len())].clone();
    let steps = 1 + rng.below(8);
    for _ in 0..steps {
        mutate_once(&mut buf, corpus, rng);
    }
    buf.truncate(max_len);
    buf
}

fn mutate_once(buf: &mut Vec<u8>, corpus: &[Vec<u8>], rng: &mut Rng) {
    match rng.below(8) {
        0 => {
            // Flip one bit.
            if !buf.is_empty() {
                let i = rng.below(buf.len());
                buf[i] ^= 1 << rng.below(8);
            }
        }
        1 => {
            // Overwrite one byte with a random value.
            if !buf.is_empty() {
                let i = rng.below(buf.len());
                buf[i] = rng.next_u64() as u8;
            }
        }
        2 => {
            // Insert one random byte.
            let i = rng.below(buf.len() + 1);
            buf.insert(i, rng.next_u64() as u8);
        }
        3 => {
            // Delete one byte.
            if !buf.is_empty() {
                let i = rng.below(buf.len());
                buf.remove(i);
            }
        }
        4 => {
            // Duplicate a short span to a random position.
            if !buf.is_empty() {
                let start = rng.below(buf.len());
                let len = (1 + rng.below(16)).min(buf.len() - start);
                let span: Vec<u8> = buf[start..start + len].to_vec();
                let at = rng.below(buf.len() + 1);
                buf.splice(at..at, span);
            }
        }
        5 => {
            // Splice in a random slice of another corpus seed.
            let donor = &corpus[rng.below(corpus.len())];
            if !donor.is_empty() {
                let start = rng.below(donor.len());
                let len = (1 + rng.below(32)).min(donor.len() - start);
                let at = rng.below(buf.len() + 1);
                buf.splice(at..at, donor[start..start + len].iter().copied());
            }
        }
        6 => {
            // Truncate.
            let keep = rng.below(buf.len() + 1);
            buf.truncate(keep);
        }
        _ => {
            // Insert a dictionary token.
            let token = TOKENS[rng.below(TOKENS.len())];
            let at = rng.below(buf.len() + 1);
            buf.splice(at..at, token.iter().copied());
        }
    }
}

/// Drive `check` over the whole corpus unmutated, then over
/// [`Options::runs`] mutated inputs. On a property violation
/// (`check` panics) the failing run's derived seed and escaped input
/// are printed before the panic propagates, so
/// `--runs 1 --seed <printed>` reproduces it in isolation.
pub fn run(target: &str, mut check: impl FnMut(&[u8])) {
    let opts = Options::from_args();
    let corpus = load_corpus(target);
    for (i, seed_input) in corpus.iter().enumerate() {
        guarded(target, &format!("corpus[{i}]"), seed_input, &mut check);
    }
    for i in 0..opts.runs {
        // Per-run stream: reproducible from the printed seed alone,
        // independent of how many runs preceded it.
        let run_seed = opts.seed.wrapping_add(i).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Rng::seed_from(run_seed);
        let input = mutate(&corpus, &mut rng, opts.max_len);
        guarded(target, &format!("run seed {run_seed:#x}"), &input, &mut check);
    }
    println!(
        "fuzz {target}: {} corpus seeds + {} mutated runs, no property violations",
        corpus.len(),
        opts.runs
    );
}

fn guarded(target: &str, label: &str, input: &[u8], check: &mut impl FnMut(&[u8])) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| check(input)));
    if let Err(panic) = outcome {
        eprintln!(
            "fuzz {target}: property violation at {label}\n  input ({} bytes): {}",
            input.len(),
            escape(input)
        );
        std::panic::resume_unwind(panic);
    }
}

fn escape(bytes: &[u8]) -> String {
    bytes
        .iter()
        .flat_map(|&b| std::ascii::escape_default(b))
        .map(char::from)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_loads_and_mutations_stay_bounded() {
        for target in ["json_pull", "serve_wire"] {
            let corpus = load_corpus(target);
            assert!(!corpus.is_empty(), "{target}: corpus must never be empty");
            let mut rng = Rng::seed_from(42);
            for _ in 0..256 {
                let input = mutate(&corpus, &mut rng, 128);
                assert!(input.len() <= 128);
            }
        }
    }

    #[test]
    fn mutation_streams_are_deterministic() {
        let corpus = load_corpus("json_pull");
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..64 {
            assert_eq!(mutate(&corpus, &mut a, 512), mutate(&corpus, &mut b, 512));
        }
    }
}
