//! Fuzz target for the streaming JSON pull parser (`json::pull`).
//!
//! Properties checked on every input:
//!
//! 1. Neither the pull parser nor the DOM parser panics, whatever the
//!    bytes.
//! 2. The pull walk terminates within the liveness bound: every
//!    non-`Eof` event consumes at least one input byte, so a document
//!    can never yield more events than bytes (+1 for the closing
//!    event of an empty-input probe).
//! 3. The DOM parser is a fold over the same event stream, so both
//!    sides must agree on well-formedness.
//! 4. Serialization stabilizes: `to_string ∘ parse` reaches a
//!    fixpoint after one normalization round. Round one may change
//!    the text legitimately — `-0.0` prints as `-0`, which reparses
//!    as the integer `0` — but round two must be byte-identical.
//!    (Huge integral floats render as integer literals outside the
//!    `i64` range, which the parser rejects by design; those skip the
//!    fixpoint check at the first reparse.)

use da4ml::json::pull::{Event, PullParser};
use da4ml::json::{parse, to_string};

fn main() {
    da4ml_fuzz::run("json_pull", |data| {
        let Ok(text) = std::str::from_utf8(data) else {
            return;
        };

        let mut parser = PullParser::new(text);
        let mut events = 0usize;
        let pull_ok = loop {
            match parser.next() {
                Ok(Event::Eof) => break true,
                Ok(_) => {
                    events += 1;
                    assert!(events <= text.len() + 1, "pull parser livelock on {text:?}");
                }
                Err(_) => break false,
            }
        };

        let dom = parse(text);
        assert_eq!(
            pull_ok,
            dom.is_ok(),
            "pull and DOM parsers disagree on the well-formedness of input {text:?}"
        );

        if let Ok(v) = dom {
            let s1 = to_string(&v);
            if let Ok(v2) = parse(&s1) {
                let s2 = to_string(&v2);
                let v3 = parse(&s2).unwrap_or_else(|e| {
                    panic!("normalized output {s2:?} failed to reparse: {e}")
                });
                assert_eq!(
                    to_string(&v3),
                    s2,
                    "serializer failed to reach a fixpoint after one round for {text:?}"
                );
            }
        }
    });
}
