//! Fuzz target for the serve wire decoder (`serve::Request`).
//!
//! The socket transport decodes request lines straight from reused
//! byte buffers ([`da4ml::serve::Request::from_json_bytes`]) while the
//! stdin transport decodes from `&str`
//! ([`da4ml::serve::Request::from_json`]). Properties checked on every
//! input:
//!
//! 1. The byte-slice entry point never panics, whatever the bytes.
//! 2. Non-UTF-8 input is a decode error, never a partial decode.
//! 3. On valid UTF-8 the two entry points agree exactly: same
//!    accept/reject verdict, identical decoded request (via `Debug`),
//!    identical error rendering — so the transports cannot drift
//!    apart on what counts as a well-formed job.

use da4ml::serve::Request;

fn main() {
    da4ml_fuzz::run("serve_wire", |data| {
        let from_bytes = Request::from_json_bytes(data);
        match std::str::from_utf8(data) {
            Ok(text) => {
                let from_str = Request::from_json(text);
                match (&from_bytes, &from_str) {
                    (Ok(a), Ok(b)) => assert_eq!(
                        format!("{a:?}"),
                        format!("{b:?}"),
                        "byte and str decoders produced different requests for {text:?}"
                    ),
                    (Err(a), Err(b)) => assert_eq!(
                        format!("{a:#}"),
                        format!("{b:#}"),
                        "byte and str decoders produced different errors for {text:?}"
                    ),
                    (a, b) => panic!(
                        "byte and str decoders disagree on {text:?}: \
                         bytes → {:?}, str → {:?}",
                        a.is_ok(),
                        b.is_ok()
                    ),
                }
            }
            Err(_) => assert!(
                from_bytes.is_err(),
                "non-UTF-8 input must be rejected, got {from_bytes:?}"
            ),
        }
    });
}
