"""Synthetic datasets with the geometry of the paper's benchmarks
(DESIGN.md §3 substitutions: the LHC datasets are not redistributable;
class-separable synthetic data with the same shapes preserves the
accuracy-vs-bitwidth and resource trends the tables measure).

All generators are deterministic in the seed and return standardized
float features (≈ zero mean, unit-ish variance, clipped to ±4).
"""

import numpy as np


def jets_hlf(n: int, seed: int = 0, n_features: int = 16, n_classes: int = 5):
    """High-level-feature jet tagging: Gaussian mixture, 5 classes.

    Class prototypes are drawn from a *fixed* seed so every split samples
    the same underlying population; `seed` only controls the sampling.
    """
    proto = np.random.default_rng(1234)
    rng = np.random.default_rng(seed)
    means = proto.normal(0.0, 1.1, (n_classes, n_features))
    scales = 0.6 + proto.random((n_classes, n_features))
    y = rng.integers(0, n_classes, n)
    x = means[y] + rng.normal(0.0, 1.0, (n, n_features)) * scales[y]
    return np.clip(x / 1.5, -4, 4).astype(np.float32), y.astype(np.int64)


def muon_tracks(n: int, seed: int = 0, bins: int = 32, stations: int = 2):
    """Muon stub hit-maps: binary occupancy of `stations`*`bins` strips;
    target is the track slope (mrad-scale regression)."""
    rng = np.random.default_rng(seed)
    theta = rng.uniform(-0.2, 0.2, n)
    x = np.zeros((n, stations * bins), dtype=np.float32)
    levers = np.linspace(20.0, 60.0, stations)
    for s, lever in enumerate(levers):
        pos = bins / 2 + theta * lever + rng.normal(0, 0.4, n)
        idx = np.clip(np.round(pos), 0, bins - 1).astype(int)
        x[np.arange(n), s * bins + idx] = 1.0
        # occasional noise hit
        noise = rng.integers(0, bins, n)
        mask = rng.random(n) < 0.15
        x[np.arange(n)[mask], s * bins + noise[mask]] = 1.0
    return x, theta.astype(np.float32)


def particles(n: int, seed: int = 0, n_particles: int = 16, n_features: int = 8,
              n_classes: int = 5):
    """Particle-cloud jets for the MLP-Mixer: [n, P, F] float features."""
    protos = np.random.default_rng(4321)
    rng = np.random.default_rng(seed)
    proto = protos.normal(0.0, 1.0, (n_classes, n_particles, n_features))
    spread = 0.5 + 0.5 * protos.random((n_classes, 1, 1))
    y = rng.integers(0, n_classes, n)
    x = proto[y] + rng.normal(0.0, 1.0, (n, n_particles, n_features)) * spread[y]
    return np.clip(x / 1.5, -4, 4).astype(np.float32), y.astype(np.int64)


def svhn_like(n: int, seed: int = 0, hw: int = 14, channels: int = 3,
              n_classes: int = 10):
    """Digit-blob images: one noisy template per class, NHWC."""
    protos = np.random.default_rng(777)
    rng = np.random.default_rng(seed)
    templates = protos.normal(0.0, 1.0, (n_classes, hw, hw, channels))
    # Smooth the templates a little so conv filters have structure to find.
    for _ in range(2):
        templates = (
            templates
            + np.roll(templates, 1, axis=1)
            + np.roll(templates, 1, axis=2)
        ) / 3.0
    y = rng.integers(0, n_classes, n)
    x = templates[y] + rng.normal(0.0, 0.8, (n, hw, hw, channels))
    return np.clip(x / 1.5, -4, 4).astype(np.float32), y.astype(np.int64)
