"""L2 — integer network forward passes in JAX, calling the L1 Pallas
kernel, plus the float training forward used by train.py.

The integer path consumes the same layer-spec dictionaries the rust NN
frontend reads from `artifacts/<name>.weights.json`, guaranteeing the
three implementations (JAX/Pallas golden model via PJRT, rust DAIS adder
graphs, rust host simulator) are bit-exact by construction:

* dense / einsum_dense / conv2d -> `kernels.cmvm.dense` (int32 matmul,
  ReLU, arithmetic shift, clip);
* conv2d is applied as an im2col CMVM over patches, in (dy, dx, cin)
  row-major patch order — identical to rust `nn::sim`;
* pooling: 2x2 stride-2 max, or average as sum >> 2.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import cmvm


def _as_i32(a):
    return jnp.asarray(a, dtype=jnp.int32)


def _dense_spec(layer, x, wb=None):
    w, b = wb if wb is not None else (
        _as_i32(np.array(layer["w"])),
        _as_i32(np.array(layer["b"])),
    )
    return cmvm.dense(
        x,
        w,
        b,
        relu=layer["relu"],
        shift=layer["shift"],
        clip_min=layer["clip_min"],
        clip_max=layer["clip_max"],
    )


COMPUTE_LAYERS = ("dense", "einsum_dense", "conv2d")


def weight_args(spec):
    """The (w, b) pairs of the compute layers, in layer order — the
    parameter convention of the AOT artifact (weights are *runtime
    parameters* of the golden model, not closed-over constants: the
    legacy xla_extension mis-executes pallas while-loops with large
    captured constants; parameters side-step it and let one executable
    serve any weight set)."""
    out = []
    for layer in spec["layers"]:
        if layer["type"] in COMPUTE_LAYERS:
            out.append(
                (
                    np.array(layer["w"], dtype=np.int32),
                    np.array(layer["b"], dtype=np.int32),
                )
            )
    return out


def _patches(x, kh, kw):
    """im2col in (dy, dx, cin) order: [batch, oh*ow, kh*kw*c]."""
    b, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(x[:, dy : dy + oh, dx : dx + ow, :])
    # [b, oh, ow, kh*kw, c] -> [b, oh*ow, kh*kw*c]
    stacked = jnp.stack(cols, axis=3)
    return stacked.reshape(b, oh * ow, kh * kw * c), oh, ow


def forward_int(spec, x, params=None):
    """Run a whole network spec on an int32 batch.

    Args:
      spec: dict with `input_shape` and `layers` (see rust nn::spec).
      x: int32 `[batch, prod(input_shape)]`.
      params: optional list of (w, b) arrays (from `weight_args` order);
        when given, the spec's embedded weights are ignored — this is the
        AOT parameterized path.

    Returns:
      int32 `[batch, n_out]`.
    """
    batch = x.shape[0]
    shape = tuple(spec["input_shape"])
    state = x.reshape((batch,) + shape)
    saved = {}
    pi = 0

    def next_wb(layer):
        nonlocal pi
        if params is None:
            return None
        wb = params[pi]
        pi += 1
        return wb

    for layer in spec["layers"]:
        ty = layer["type"]
        if ty == "dense":
            state = _dense_spec(layer, state.reshape(batch, -1), next_wb(layer))
        elif ty == "einsum_dense":
            wb = next_wb(layer)
            b_, p, f = state.shape
            if layer["axis"] == "feature":
                out = _dense_spec(layer, state.reshape(b_ * p, f), wb)
                state = out.reshape(b_, p, -1)
            else:  # particle axis: transpose, mix, transpose back
                xt = jnp.swapaxes(state, 1, 2).reshape(b_ * f, p)
                out = _dense_spec(layer, xt, wb)
                state = jnp.swapaxes(out.reshape(b_, f, -1), 1, 2)
        elif ty == "conv2d":
            wb = next_wb(layer)
            kh, kw = layer["kh"], layer["kw"]
            pat, oh, ow = _patches(state, kh, kw)
            flat = pat.reshape(batch * oh * ow, -1)
            out = _dense_spec(layer, flat, wb)
            state = out.reshape(batch, oh, ow, -1)
        elif ty in ("max_pool2d", "avg_pool2d"):
            b_, h, w, c = state.shape
            v = state[:, : h - h % 2, : w - w % 2, :]
            v = v.reshape(b_, h // 2, 2, w // 2, 2, c)
            if ty == "max_pool2d":
                state = jnp.max(v, axis=(2, 4))
            else:
                state = jnp.right_shift(jnp.sum(v, axis=(2, 4)), 2)
        elif ty == "flatten":
            state = state.reshape(batch, -1)
        elif ty == "save":
            saved[layer["tag"]] = state
        elif ty == "add_saved":
            state = state + saved[layer["tag"]]
        else:
            raise ValueError(f"unknown layer type {ty}")
    return state.reshape(batch, -1)


def lower_hlo_text(spec, batch: int = 1) -> str:
    """Lower the integer forward pass to HLO text for the rust runtime.

    HLO *text* (not serialized protos) is the interchange format: jax
    >= 0.5 emits 64-bit instruction ids which xla_extension 0.5.1
    rejects; the text parser reassigns ids (see /opt/xla-example).
    The lowered function takes a flat int32 input `[n]` (batch folded)
    followed by the (w, b) pairs of every compute layer (`weight_args`
    order) and returns a tuple with one int32 output `[n_out]`.
    """
    from jax._src.lib import xla_client as xc

    n_in = int(np.prod(spec["input_shape"]))
    wargs = weight_args(spec)

    def fn(x, *flat):
        params = [(flat[2 * i], flat[2 * i + 1]) for i in range(len(wargs))]
        out = forward_int(spec, x.reshape(1, n_in), params)
        return (out.reshape(-1),)

    arg = [jax.ShapeDtypeStruct((n_in,), jnp.int32)]
    for w, b in wargs:
        arg.append(jax.ShapeDtypeStruct(w.shape, jnp.int32))
        arg.append(jax.ShapeDtypeStruct(b.shape, jnp.int32))
    lowered = jax.jit(fn).lower(*arg)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Float forward passes for training (same topology, float32).
# ---------------------------------------------------------------------------


def float_forward(params, arch, x):
    """Float forward for training. `arch` is a list of float-layer tuples
    mirroring the spec layers; params is a pytree of (w, b) pairs."""
    saved = {}
    state = x
    pi = 0
    for layer in arch:
        ty = layer[0]
        if ty == "dense":
            w, b = params[pi]
            pi += 1
            state = state.reshape(state.shape[0], -1) @ w + b
            if layer[1]:
                state = jax.nn.relu(state)
        elif ty == "einsum":
            w, b = params[pi]
            pi += 1
            axis, relu = layer[1], layer[2]
            if axis == "feature":
                state = state @ w + b
            else:
                state = jnp.einsum("bpf,pq->bqf", state, w) + b[None, :, None]
            if relu:
                state = jax.nn.relu(state)
        elif ty == "conv":
            w, b = params[pi]
            pi += 1
            kh = layer[1]
            pat, oh, ow = _patches(state, kh, kh)
            out = pat @ w + b
            state = jax.nn.relu(out).reshape(state.shape[0], oh, ow, -1)
        elif ty == "maxpool":
            b_, h, w_, c = state.shape
            v = state[:, : h - h % 2, : w_ - w_ % 2, :]
            state = v.reshape(b_, h // 2, 2, w_ // 2, 2, c).max(axis=(2, 4))
        elif ty == "save":
            saved[layer[1]] = state
        elif ty == "add":
            state = state + saved[layer[1]]
        elif ty == "flatten":
            state = state.reshape(state.shape[0], -1)
    return state
