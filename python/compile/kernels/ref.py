"""Pure-jnp oracle for the Pallas CMVM kernel (the L1 correctness
reference) plus a plain-numpy integer model mirroring rust `nn::sim`.
"""

import jax.numpy as jnp
import numpy as np


def requant(z, relu: bool, shift: int, clip_min: int, clip_max: int):
    """Reference requantization: ReLU -> arithmetic shift -> clip."""
    if relu:
        z = jnp.maximum(z, 0)
    if shift > 0:
        z = jnp.right_shift(z, shift)
    elif shift < 0:
        z = jnp.left_shift(z, -shift)
    return jnp.clip(z, clip_min, clip_max)


def dense(x, w, b, *, relu: bool, shift: int, clip_min: int, clip_max: int):
    """Reference quantized dense layer (same signature as kernels.cmvm)."""
    z = (
        jnp.matmul(
            x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
        )
        + b.astype(jnp.int32)[None, :]
    )
    return requant(z, relu, shift, clip_min, clip_max)


def dense_np(x, w, b, *, relu: bool, shift: int, clip_min: int, clip_max: int):
    """Numpy int64 reference (overflow-free ground truth)."""
    z = x.astype(np.int64) @ w.astype(np.int64) + b.astype(np.int64)[None, :]
    if relu:
        z = np.maximum(z, 0)
    if shift > 0:
        z = z >> shift
    elif shift < 0:
        z = z << -shift
    return np.clip(z, clip_min, clip_max)
