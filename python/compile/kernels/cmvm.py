"""L1 — the Pallas CMVM kernel (the paper's compute hot-spot).

The quantized dense layer `y = requant(x @ W + b)` is the CMVM the
da4ml compiler unrolls into adder graphs on the FPGA side. Here the same
computation is expressed as a Pallas kernel so the L2 JAX model lowers
it into the AOT HLO artifact the rust runtime executes as the *golden
model*.

Hardware adaptation (DESIGN.md §3): the paper's target is a fully
unrolled FPGA adder fabric. On TPU the analogous structure is an MXU
tile: the kernel blocks the output dimension (`d_out`) so each grid step
works on a VMEM-resident `(d_in, block_n)` weight tile with int32
accumulation — the systolic-array counterpart of the paper's spatial
unrolling. ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness (bit-exactness vs the rust DAIS
simulation) is the deliverable on this testbed.

Integer semantics (shared bit-exactly with rust `nn::sim` and the DAIS
programs): int32 accumulation, optional ReLU, **arithmetic** right shift
(floor), saturation to `[clip_min, clip_max]`.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _requant(z, relu: bool, shift: int, clip_min: int, clip_max: int):
    """Shared requantization epilogue (ReLU -> floor-shift -> clip)."""
    if relu:
        z = jnp.maximum(z, 0)
    if shift > 0:
        z = jnp.right_shift(z, shift)  # arithmetic on signed ints
    elif shift < 0:
        z = jnp.left_shift(z, -shift)
    return jnp.clip(z, clip_min, clip_max)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu, shift, clip_min, clip_max):
    """One grid step: full batch × one block of output columns."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    z = acc + b_ref[...][None, :]
    o_ref[...] = _requant(z, relu, shift, clip_min, clip_max)


def dense(
    x,
    w,
    b,
    *,
    relu: bool,
    shift: int,
    clip_min: int,
    clip_max: int,
    block_n: int = 64,
):
    """Quantized dense layer as a Pallas kernel.

    Args:
      x: int32 `[batch, d_in]` activations.
      w: int32 `[d_in, d_out]` weights.
      b: int32 `[d_out]` bias (pre-shift scale).
      relu: apply ReLU before the shift.
      shift: arithmetic right-shift of the requantizer (may be <= 0).
      clip_min / clip_max: saturation bounds.
      block_n: output-column tile width (the VMEM/MXU tile knob).

    Returns:
      int32 `[batch, d_out]` requantized outputs.
    """
    batch, d_in = x.shape
    d_in_w, d_out = w.shape
    assert d_in == d_in_w and b.shape == (d_out,)
    block_n = min(block_n, d_out)
    # Pad d_out to a multiple of block_n so the grid tiles exactly.
    pad = (-d_out) % block_n
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        b = jnp.pad(b, (0, pad))
    n_padded = d_out + pad
    grid = (n_padded // block_n,)

    out = pl.pallas_call(
        partial(
            _dense_kernel,
            relu=relu,
            shift=shift,
            clip_min=clip_min,
            clip_max=clip_max,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, d_in), lambda i: (0, 0)),
            pl.BlockSpec((d_in, block_n), lambda i: (0, i)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((batch, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, n_padded), jnp.int32),
        interpret=True,  # CPU path; Mosaic lowering is TPU-only
    )(x.astype(jnp.int32), w.astype(jnp.int32), b.astype(jnp.int32))
    return out[:, :d_out]
