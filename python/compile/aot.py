"""AOT artifact builder (`make artifacts`): trains the benchmark
networks, integerizes them at every quantization level, and exports

* ``<name>_w{W}a{A}.weights.json``  — layer spec for the rust frontend
* ``<name>_w{W}a{A}.testvec.json``  — integer inputs + golden outputs
* ``<name>.weights.json``           — alias of the finest level
* ``<name>.hlo.txt``                — integer forward pass as HLO text
* ``model.hlo.txt``                 — alias of jet_mlp (Makefile target)
* ``metrics.json``                  — accuracy / resolution per level

HLO **text** is the interchange format (not serialized protos): jax
>= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
while the text parser reassigns ids (see /opt/xla-example/README.md).

Python runs once here and never on the rust request path.
"""

import argparse
import json
import os

import numpy as np

from . import quant
from .model import forward_int, lower_hlo_text
from .train import BUILDERS, LEVELS

N_TESTVEC = 256  # vectors exported for rust golden cross-checking
N_METRIC = 4000  # vectors used for the accuracy/resolution metrics


def _int_inputs(name, x, a_bits):
    if name == "muon":
        return quant.binary_input(x)
    return quant.quantize_input(x, a_bits)


def _metric(name, outputs, labels, a_bits):
    """Accuracy for classifiers; truncated-MSE resolution (mrad-like
    units) for the muon regression."""
    if name == "muon":
        s = quant.act_scale(a_bits) * 10.0  # target was scaled by 10
        pred = outputs[:, 0] / s
        err = np.clip(pred - labels, -0.05, 0.05)  # truncated MSE
        return {"resolution_mrad": float(np.sqrt(np.mean(err**2)) * 1000.0)}
    acc = float(np.mean(np.argmax(outputs, axis=1) == labels))
    return {"accuracy": acc}


def build_all(outdir: str, models=None, force: bool = False) -> None:
    os.makedirs(outdir, exist_ok=True)
    manifest_path = os.path.join(outdir, "metrics.json")
    if os.path.exists(manifest_path) and not force:
        print(f"{manifest_path} exists; skipping (use --force to rebuild)")
        return

    metrics = {}
    for name, builder in BUILDERS.items():
        if models and name not in models:
            continue
        print(f"[aot] training {name} ...")
        _, _, _, (xt, yt), make_spec = builder()
        metrics[name] = {}
        for w_bits, a_bits in LEVELS:
            tag = f"{name}_w{w_bits}a{a_bits}"
            spec = make_spec(w_bits, a_bits)
            with open(os.path.join(outdir, f"{tag}.weights.json"), "w") as f:
                json.dump(spec, f)

            # Integer golden outputs via the L2/L1 path (Pallas kernel).
            xi = _int_inputs(name, xt, a_bits)
            out = np.array(
                forward_int(spec, xi[:N_METRIC].astype(np.int32))
            )
            m = _metric(name, out[:N_METRIC], yt[:N_METRIC], a_bits)
            m["w_bits"], m["a_bits"] = w_bits, a_bits
            metrics[name][f"w{w_bits}a{a_bits}"] = m

            vec = {
                "inputs": xi[:N_TESTVEC].reshape(min(N_TESTVEC, len(xi)), -1)
                .astype(int)
                .tolist(),
                "outputs": out[:N_TESTVEC].astype(int).tolist(),
            }
            if name != "muon":
                vec["labels"] = yt[:N_TESTVEC].astype(int).tolist()
            with open(os.path.join(outdir, f"{tag}.testvec.json"), "w") as f:
                json.dump(vec, f)
            print(f"[aot]   {tag}: {m}")

        # Finest level is the canonical artifact + HLO golden model.
        w_bits, a_bits = LEVELS[0]
        spec = make_spec(w_bits, a_bits)
        with open(os.path.join(outdir, f"{name}.weights.json"), "w") as f:
            json.dump(spec, f)
        tag = f"{name}_w{w_bits}a{a_bits}"
        for suffix in ("testvec",):
            src = os.path.join(outdir, f"{tag}.{suffix}.json")
            dst = os.path.join(outdir, f"{name}.{suffix}.json")
            with open(src) as f_in, open(dst, "w") as f_out:
                f_out.write(f_in.read())
        print(f"[aot] lowering {name} to HLO text ...")
        hlo = lower_hlo_text(spec)
        with open(os.path.join(outdir, f"{name}.hlo.txt"), "w") as f:
            f.write(hlo)

    # Makefile's canonical artifact.
    jet = os.path.join(outdir, "jet_mlp.hlo.txt")
    if os.path.exists(jet):
        with open(jet) as f_in, open(os.path.join(outdir, "model.hlo.txt"), "w") as f_out:
            f_out.write(f_in.read())

    with open(manifest_path, "w") as f:
        json.dump(metrics, f, indent=1)
    print(f"[aot] wrote {manifest_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--models", nargs="*", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    build_all(args.out, models=args.models, force=args.force)


if __name__ == "__main__":
    main()
