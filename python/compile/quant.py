"""HGQ-like post-training integerization (the quantization substrate).

The paper's networks are trained with HGQ (per-weight bitwidths). Here
we reproduce the *consumable artifact* of that flow — heavily quantized
integer networks whose accuracy degrades as the bit budget shrinks — via
power-of-two-scale post-training quantization:

* activations: uniform scale ``s_a = 2^(a_bits-3)`` (float range ±4,
  inputs standardized), signed clip to ``a_bits``;
* weights: per-layer power-of-two scale ``2^k`` maximizing use of
  ``w_bits``;
* bias: integerized at the accumulator scale ``s_a * 2^k``;
* requantizer: shift ``k`` (exact — all scales are powers of two), so
  every layer's output returns to scale ``s_a``.

Power-of-two scales make every rescaling an exact arithmetic shift,
which is what allows the rust DAIS adder graphs, the JAX/Pallas golden
model and the plain-integer simulators to agree **bit-exactly**.
"""

import numpy as np


def act_scale(a_bits: int) -> int:
    """Activation scale 2^(a_bits-3): float range [-4, 4)."""
    return 1 << max(a_bits - 3, 0)


def act_clip(a_bits: int):
    """Signed clip bounds of an a_bits activation."""
    return -(1 << (a_bits - 1)), (1 << (a_bits - 1)) - 1


def weight_scale_pow2(w: np.ndarray, w_bits: int) -> int:
    """Largest power-of-two exponent k with round(w * 2^k) within w_bits."""
    wmax = float(np.max(np.abs(w))) if w.size else 1.0
    if wmax == 0.0:
        return 0
    limit = (1 << (w_bits - 1)) - 1
    k = int(np.floor(np.log2(limit / wmax)))
    return max(k, 0)


def quantize_dense(w: np.ndarray, b: np.ndarray, w_bits: int, a_bits: int):
    """Integerize one dense layer; returns (w_int, b_int, shift)."""
    k = weight_scale_pow2(w, w_bits)
    limit = (1 << (w_bits - 1)) - 1
    w_int = np.clip(np.round(w * (1 << k)), -limit - 1, limit).astype(np.int64)
    s_a = act_scale(a_bits)
    b_int = np.round(b * s_a * (1 << k)).astype(np.int64)
    return w_int, b_int, k


def quantize_input(x: np.ndarray, a_bits: int) -> np.ndarray:
    """Standardized float inputs -> signed a_bits integers."""
    s_a = act_scale(a_bits)
    lo, hi = act_clip(a_bits)
    return np.clip(np.round(x * s_a), lo, hi).astype(np.int64)


def binary_input(x: np.ndarray) -> np.ndarray:
    """1-bit inputs (muon hit maps): {0, 1} integers, no scaling."""
    return (x > 0.5).astype(np.int64)
