"""Build-time training of the four benchmark networks (float), followed
by post-training integerization at several quantization levels.

Everything here runs exactly once per `make artifacts`; nothing from
this module is on the rust request path.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import quant
from .model import float_forward

# Quantization sweep: (weight_bits, act_bits), mirroring the paper's six
# per-table quantization levels from finest to coarsest.
LEVELS = [(8, 8), (7, 7), (6, 6), (5, 6), (4, 6), (4, 5)]


def _init_dense(rng, d_in, d_out):
    w = rng.normal(0.0, np.sqrt(2.0 / d_in), (d_in, d_out)).astype(np.float32)
    b = np.zeros(d_out, dtype=np.float32)
    return jnp.array(w), jnp.array(b)


def _adam(params, grads, state, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t = t + 1
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
    params = jax.tree.map(lambda p, a, b_: p - lr * a / (jnp.sqrt(b_) + eps), params, mh, vh)
    return params, (m, v, t)


def _train(arch, params, x, y, *, steps, batch, loss_kind, seed=0):
    rng = np.random.default_rng(seed)

    def loss_fn(p, xb, yb):
        out = float_forward(p, arch, xb)
        if loss_kind == "ce":
            logp = jax.nn.log_softmax(out)
            return -jnp.mean(logp[jnp.arange(xb.shape[0]), yb])
        return jnp.mean((out.reshape(-1) - yb) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    state = (
        jax.tree.map(jnp.zeros_like, params),
        jax.tree.map(jnp.zeros_like, params),
        0,
    )
    for _ in range(steps):
        idx = rng.integers(0, x.shape[0], batch)
        _, grads = grad_fn(params, jnp.array(x[idx]), jnp.array(y[idx]))
        params, state = _adam(params, grads, state)
    return params


# ---------------------------------------------------------------------------
# Architectures: (float arch for training, spec-layer builder).
# ---------------------------------------------------------------------------


def _spec_dense(w, b, relu, shift, a_bits, wide=False):
    lo, hi = quant.act_clip(16 if wide else a_bits)
    return {
        "type": "dense",
        "w": w.tolist(),
        "b": b.tolist(),
        "relu": bool(relu),
        "shift": int(shift),
        "clip_min": int(lo),
        "clip_max": int(hi),
    }


def _quantize_chain(params, relus, w_bits, a_bits, kinds=None, extra=None):
    """Integerize a chain of dense-like layers into spec layer dicts."""
    kinds = kinds or ["dense"] * len(params)
    extra = extra or [{}] * len(params)
    layers = []
    for i, ((w, b), relu) in enumerate(zip(params, relus)):
        w_np = np.asarray(w, dtype=np.float64)
        b_np = np.asarray(b, dtype=np.float64)
        w_int, b_int, k = quant.quantize_dense(w_np, b_np, w_bits, a_bits)
        wide = i == len(params) - 1  # final layer keeps 16-bit outputs
        lo, hi = quant.act_clip(16 if wide else a_bits)
        layer = {
            "type": kinds[i],
            "w": w_int.tolist(),
            "b": b_int.tolist(),
            "relu": bool(relu),
            "shift": int(k),
            "clip_min": int(lo),
            "clip_max": int(hi),
        }
        layer.update(extra[i])
        layers.append(layer)
    return layers


def build_jet_mlp(seed=0):
    """16 -> 64 -> 32 -> 16 -> 16 -> 5 dense chain (paper §6.2.1)."""
    rng = np.random.default_rng(seed)
    dims = [16, 64, 32, 16, 16, 5]
    arch = [("dense", i < len(dims) - 2) for i in range(len(dims) - 1)]
    params = [_init_dense(rng, dims[i], dims[i + 1]) for i in range(len(dims) - 1)]
    x, y = data_mod.jets_hlf(20000, seed=1)
    params = _train(arch, params, x, y, steps=400, batch=256, loss_kind="ce")
    xt, yt = data_mod.jets_hlf(4000, seed=2)

    def make_spec(w_bits, a_bits):
        relus = [a[1] for a in arch]
        layers = _quantize_chain(params, relus, w_bits, a_bits)
        return {
            "name": "jet_mlp",
            "input_bits": a_bits,
            "input_signed": True,
            "input_shape": [16],
            "layers": layers,
        }

    return params, arch, (x, y), (xt, yt), make_spec


def build_muon(seed=0):
    """Binary hit-map regression 64 -> 32 -> 32 -> 16 -> 1 (paper §6.2.3)."""
    rng = np.random.default_rng(seed)
    dims = [64, 32, 32, 16, 1]
    arch = [("dense", i < len(dims) - 2) for i in range(len(dims) - 1)]
    params = [_init_dense(rng, dims[i], dims[i + 1]) for i in range(len(dims) - 1)]
    x, y = data_mod.muon_tracks(20000, seed=3)
    # Scale the target so the regression output sits in the act range.
    params = _train(arch, params, x, y * 10.0, steps=500, batch=256, loss_kind="mse")
    xt, yt = data_mod.muon_tracks(4000, seed=4)

    def make_spec(w_bits, a_bits):
        relus = [a[1] for a in arch]
        layers = _quantize_chain(params, relus, w_bits, a_bits)
        return {
            "name": "muon",
            "input_bits": 1,
            "input_signed": False,
            "input_shape": [64],
            "layers": layers,
        }

    return params, arch, (x, y), (xt, yt), make_spec


def build_mixer(seed=0):
    """MLP-Mixer jet tagger on [16 particles x 8 features] with one skip
    connection (paper §6.2.4, scaled geometry)."""
    rng = np.random.default_rng(seed)
    P, F = 16, 8
    arch = [
        ("save", "skip"),
        ("einsum", "feature", True),
        ("einsum", "particle", True),
        ("add", "skip"),
        ("einsum", "feature", True),
        ("einsum", "particle", True),
        ("flatten",),
        ("dense", True),
        ("dense", False),
    ]
    params = [
        _init_dense(rng, F, F),
        _init_dense(rng, P, P),
        _init_dense(rng, F, F),
        _init_dense(rng, P, P),
        _init_dense(rng, P * F, 32),
        _init_dense(rng, 32, 5),
    ]
    x, y = data_mod.particles(20000, seed=5, n_particles=P, n_features=F)
    params = _train(arch, params, x, y, steps=400, batch=128, loss_kind="ce")
    xt, yt = data_mod.particles(4000, seed=6, n_particles=P, n_features=F)

    def make_spec(w_bits, a_bits):
        dense_params = params
        relus = [True, True, True, True, True, False]
        kinds = [
            "einsum_dense",
            "einsum_dense",
            "einsum_dense",
            "einsum_dense",
            "dense",
            "dense",
        ]
        extra = [
            {"axis": "feature"},
            {"axis": "particle"},
            {"axis": "feature"},
            {"axis": "particle"},
            {},
            {},
        ]
        qlayers = _quantize_chain(dense_params, relus, w_bits, a_bits, kinds, extra)
        layers = [
            {"type": "save", "tag": "skip"},
            qlayers[0],
            qlayers[1],
            {"type": "add_saved", "tag": "skip"},
            qlayers[2],
            qlayers[3],
            {"type": "flatten"},
            qlayers[4],
            qlayers[5],
        ]
        return {
            "name": "mixer",
            "input_bits": a_bits,
            "input_signed": True,
            "input_shape": [P, F],
            "layers": layers,
        }

    return params, arch, (x, y), (xt, yt), make_spec


def build_svhn(seed=0):
    """LeNet-like conv net on 14x14x3 digit blobs (paper §6.2.2, scaled)."""
    rng = np.random.default_rng(seed)
    arch = [
        ("conv", 3),  # 14 -> 12, 8 ch
        ("maxpool",),  # 12 -> 6
        ("conv", 3),  # 6 -> 4, 12 ch
        ("maxpool",),  # 4 -> 2
        ("flatten",),
        ("dense", True),
        ("dense", False),
    ]
    params = [
        _init_dense(rng, 3 * 9, 8),
        _init_dense(rng, 8 * 9, 12),
        _init_dense(rng, 2 * 2 * 12, 32),
        _init_dense(rng, 32, 10),
    ]
    x, y = data_mod.svhn_like(12000, seed=7)
    params = _train(arch, params, x, y, steps=300, batch=128, loss_kind="ce")
    xt, yt = data_mod.svhn_like(3000, seed=8)

    def make_spec(w_bits, a_bits):
        relus = [True, True, True, False]
        kinds = ["conv2d", "conv2d", "dense", "dense"]
        extra = [{"kh": 3, "kw": 3}, {"kh": 3, "kw": 3}, {}, {}]
        qlayers = _quantize_chain(params, relus, w_bits, a_bits, kinds, extra)
        layers = [
            qlayers[0],
            {"type": "max_pool2d"},
            qlayers[1],
            {"type": "max_pool2d"},
            {"type": "flatten"},
            qlayers[2],
            qlayers[3],
        ]
        return {
            "name": "svhn",
            "input_bits": a_bits,
            "input_signed": True,
            "input_shape": [14, 14, 3],
            "layers": layers,
        }

    return params, arch, (x, y), (xt, yt), make_spec


BUILDERS = {
    "jet_mlp": build_jet_mlp,
    "muon": build_muon,
    "mixer": build_mixer,
    "svhn": build_svhn,
}
