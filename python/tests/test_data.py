"""Synthetic-dataset substrate tests: determinism, shapes, population
consistency across splits (the bug class that silently destroys the
accuracy columns)."""

import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile import data  # noqa: E402


def test_jets_deterministic_and_split_consistent():
    x1, y1 = data.jets_hlf(100, seed=5)
    x2, y2 = data.jets_hlf(100, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    # Different sampling seeds share the class population: a nearest-
    # class-mean classifier fit on one split must beat chance on another.
    xa, ya = data.jets_hlf(4000, seed=1)
    xb, yb = data.jets_hlf(2000, seed=2)
    means = np.stack([xa[ya == c].mean(0) for c in range(5)])
    pred = np.argmin(((xb[:, None, :] - means[None]) ** 2).sum(-1), axis=1)
    acc = np.mean(pred == yb)
    assert acc > 0.6, f"cross-split accuracy {acc} — populations diverge"


def test_jets_range_and_shape():
    x, y = data.jets_hlf(500, seed=0)
    assert x.shape == (500, 16) and y.shape == (500,)
    assert np.all(np.abs(x) <= 4.0)
    assert set(np.unique(y)) <= set(range(5))


def test_muon_binary_and_informative():
    x, theta = data.muon_tracks(2000, seed=0)
    assert x.shape == (2000, 64)
    assert set(np.unique(x)) <= {0.0, 1.0}
    assert np.all(np.abs(theta) <= 0.2)
    # Hit positions must correlate with the slope (a linear readout on
    # the hit map beats predicting the mean).
    w, *_ = np.linalg.lstsq(x, theta, rcond=None)
    resid = theta - x @ w
    assert resid.var() < 0.5 * theta.var()


def test_particles_shapes():
    x, y = data.particles(100, seed=0, n_particles=16, n_features=8)
    assert x.shape == (100, 16, 8)
    assert y.shape == (100,)


def test_svhn_like_class_structure():
    x, y = data.svhn_like(1000, seed=0)
    assert x.shape == (1000, 14, 14, 3)
    # Same-class images must be closer to their class template than to
    # other templates on average.
    t0 = x[y == 0].mean(0)
    t1 = x[y == 1].mean(0)
    d00 = np.mean((x[y == 0] - t0) ** 2)
    d01 = np.mean((x[y == 0] - t1) ** 2)
    assert d00 < d01
