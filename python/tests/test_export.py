"""Exporter integration: the spec dictionaries produced by train.py
must decode in the rust frontend format and degrade gracefully with
quantization level (accuracy monotonic-ish in bits)."""

import json

import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile import quant  # noqa: E402
from compile.model import forward_int  # noqa: E402
from compile.train import build_jet_mlp  # noqa: E402


def test_jet_mlp_spec_schema_and_accuracy():
    _, _, _, (xt, yt), make_spec = build_jet_mlp()
    accs = {}
    for (w_bits, a_bits) in [(8, 8), (4, 5)]:
        spec = make_spec(w_bits, a_bits)
        # Schema checks (must match rust nn::spec field names).
        assert set(spec) == {"name", "input_bits", "input_signed",
                             "input_shape", "layers"}
        for layer in spec["layers"]:
            assert layer["type"] == "dense"
            assert set(layer) == {"type", "w", "b", "relu", "shift",
                                  "clip_min", "clip_max"}
        # JSON-serializable with exact ints.
        text = json.dumps(spec)
        assert json.loads(text) == spec

        xi = quant.quantize_input(xt[:1000], a_bits).astype(np.int32)
        out = np.array(forward_int(spec, xi))
        accs[w_bits] = float(np.mean(np.argmax(out, 1) == yt[:1000]))

    # The quantized model must actually classify (5 classes, chance 0.2),
    # and the finer level must not be (much) worse than the coarser.
    assert accs[8] > 0.5, accs
    assert accs[8] >= accs[4] - 0.02, accs
