"""L1 correctness: the Pallas CMVM kernel vs the pure-jnp oracle vs the
overflow-free numpy reference — the core correctness signal, swept over
shapes, bitwidths, shifts and clip ranges with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile.kernels import cmvm, ref  # noqa: E402


def _rand_case(rng, batch, d_in, d_out, x_bits, w_bits):
    x = rng.integers(-(1 << (x_bits - 1)), 1 << (x_bits - 1), (batch, d_in))
    w = rng.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1), (d_in, d_out))
    b = rng.integers(-(1 << w_bits), 1 << w_bits, (d_out,))
    return x.astype(np.int32), w.astype(np.int32), b.astype(np.int32)


@settings(max_examples=40, deadline=None)
@given(
    batch=st.integers(1, 5),
    d_in=st.integers(1, 24),
    d_out=st.integers(1, 20),
    x_bits=st.integers(2, 8),
    w_bits=st.integers(2, 8),
    shift=st.integers(-2, 8),
    relu=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_kernel_vs_references(batch, d_in, d_out, x_bits, w_bits, shift, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand_case(rng, batch, d_in, d_out, x_bits, w_bits)
    clip_min, clip_max = -(1 << 12), (1 << 12) - 1
    kw = dict(relu=relu, shift=shift, clip_min=clip_min, clip_max=clip_max)
    got = np.array(cmvm.dense(jnp.array(x), jnp.array(w), jnp.array(b), **kw))
    oracle = np.array(ref.dense(jnp.array(x), jnp.array(w), jnp.array(b), **kw))
    truth = ref.dense_np(x, w, b, **kw)
    np.testing.assert_array_equal(got, truth)
    np.testing.assert_array_equal(oracle, truth)


@pytest.mark.parametrize("block_n", [1, 3, 8, 64, 128])
def test_kernel_blocking_invariant(block_n):
    """The VMEM tile width must not change the result."""
    rng = np.random.default_rng(0)
    x, w, b = _rand_case(rng, 4, 16, 20, 8, 6)
    kw = dict(relu=True, shift=4, clip_min=-128, clip_max=127)
    base = ref.dense_np(x, w, b, **kw)
    got = np.array(
        cmvm.dense(jnp.array(x), jnp.array(w), jnp.array(b), block_n=block_n, **kw)
    )
    np.testing.assert_array_equal(got, base)


def test_negative_shift_is_left_shift():
    x = np.array([[1, -2]], dtype=np.int32)
    w = np.eye(2, dtype=np.int32)
    b = np.zeros(2, dtype=np.int32)
    out = np.array(
        cmvm.dense(
            jnp.array(x), jnp.array(w), jnp.array(b),
            relu=False, shift=-3, clip_min=-100, clip_max=100,
        )
    )
    np.testing.assert_array_equal(out, [[8, -16]])


def test_arithmetic_shift_floors_negatives():
    # -13 >> 2 must be -4 (floor), not -3 (truncation).
    x = np.array([[-13]], dtype=np.int32)
    w = np.array([[1]], dtype=np.int32)
    b = np.zeros(1, dtype=np.int32)
    out = np.array(
        cmvm.dense(
            jnp.array(x), jnp.array(w), jnp.array(b),
            relu=False, shift=2, clip_min=-100, clip_max=100,
        )
    )
    assert out[0, 0] == -4


def test_saturation_bounds():
    x = np.array([[127]], dtype=np.int32)
    w = np.array([[127]], dtype=np.int32)
    b = np.zeros(1, dtype=np.int32)
    out = np.array(
        cmvm.dense(
            jnp.array(x), jnp.array(w), jnp.array(b),
            relu=False, shift=0, clip_min=-128, clip_max=127,
        )
    )
    assert out[0, 0] == 127
