"""Quantization-substrate tests: power-of-two scales, exactness of the
integer pipeline, accuracy degradation with shrinking bit budgets."""

import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile import quant  # noqa: E402


def test_act_scale_and_clip():
    assert quant.act_scale(8) == 32  # range [-4, 4)
    assert quant.act_clip(8) == (-128, 127)
    assert quant.act_clip(4) == (-8, 7)


def test_weight_scale_uses_full_budget():
    w = np.array([[0.7, -0.3], [0.1, 0.49]])
    for bits in (3, 4, 6, 8):
        k = quant.weight_scale_pow2(w, bits)
        limit = (1 << (bits - 1)) - 1
        wi = np.round(w * (1 << k))
        assert np.max(np.abs(wi)) <= limit
        # One more doubling would overflow the budget.
        assert np.max(np.abs(np.round(w * (1 << (k + 1))))) > limit


def test_quantize_dense_shift_consistency():
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.5, (8, 4))
    b = rng.normal(0, 0.2, 4)
    w_int, b_int, k = quant.quantize_dense(w, b, 6, 8)
    # Dequantized product scale: x_int = x*32, z = x_int @ w_int + b_int
    # ~ 32 * 2^k * (x @ w + b); shifting by k returns to scale 32.
    x = rng.normal(0, 1, (16, 8))
    x_int = quant.quantize_input(x, 8)
    z = x_int @ w_int + b_int
    approx = (z / (1 << k)) / 32.0
    want = (x_int / 32.0) @ w + b
    assert np.max(np.abs(approx - want)) < 0.15


def test_binary_input():
    x = np.array([0.0, 1.0, 0.4, 0.9])
    np.testing.assert_array_equal(quant.binary_input(x), [0, 1, 0, 1])


def test_zero_weights():
    w_int, b_int, k = quant.quantize_dense(np.zeros((3, 2)), np.zeros(2), 4, 8)
    assert k == 0
    assert np.all(w_int == 0)
