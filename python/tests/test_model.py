"""L2 correctness: the JAX integer network forward (which the HLO golden
model is lowered from) vs an independent pure-python integer simulator
mirroring rust nn::sim."""

import numpy as np
from hypothesis import given, settings, strategies as st

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile.model import forward_int, lower_hlo_text  # noqa: E402


def _pysim(spec, x):
    """Independent integer reference (mirrors rust nn::sim)."""
    def requant(z, relu, shift, lo, hi):
        if relu:
            z = max(z, 0)
        if shift > 0:
            z >>= shift
        elif shift < 0:
            z <<= -shift
        return min(max(z, lo), hi)

    state = list(int(v) for v in x)
    shape = list(spec["input_shape"])
    saved = {}
    for layer in spec["layers"]:
        ty = layer["type"]
        if ty == "dense":
            w, b = layer["w"], layer["b"]
            out = []
            for i in range(len(b)):
                z = b[i] + sum(state[j] * w[j][i] for j in range(len(w)))
                out.append(
                    requant(z, layer["relu"], layer["shift"],
                            layer["clip_min"], layer["clip_max"])
                )
            state, shape = out, [len(out)]
        elif ty == "einsum_dense":
            p, f = shape
            w, b = layer["w"], layer["b"]
            d_out = len(b)
            if layer["axis"] == "feature":
                out = [0] * (p * d_out)
                for r in range(p):
                    for i in range(d_out):
                        z = b[i] + sum(
                            state[r * f + j] * w[j][i] for j in range(f)
                        )
                        out[r * d_out + i] = requant(
                            z, layer["relu"], layer["shift"],
                            layer["clip_min"], layer["clip_max"])
                state, shape = out, [p, d_out]
            else:
                out = [0] * (d_out * f)
                for c in range(f):
                    for i in range(d_out):
                        z = b[i] + sum(
                            state[r * f + c] * w[r][i] for r in range(p)
                        )
                        out[i * f + c] = requant(
                            z, layer["relu"], layer["shift"],
                            layer["clip_min"], layer["clip_max"])
                state, shape = out, [d_out, f]
        elif ty == "conv2d":
            h, w_, c = shape
            kh, kw = layer["kh"], layer["kw"]
            oh, ow = h - kh + 1, w_ - kw + 1
            wt, b = layer["w"], layer["b"]
            cout = len(b)
            out = []
            for oy in range(oh):
                for ox in range(ow):
                    patch = []
                    for dy in range(kh):
                        for dx in range(kw):
                            base = ((oy + dy) * w_ + (ox + dx)) * c
                            patch.extend(state[base:base + c])
                    for i in range(cout):
                        z = b[i] + sum(patch[j] * wt[j][i] for j in range(len(wt)))
                        out.append(requant(z, layer["relu"], layer["shift"],
                                           layer["clip_min"], layer["clip_max"]))
            state, shape = out, [oh, ow, cout]
        elif ty in ("max_pool2d", "avg_pool2d"):
            h, w_, c = shape
            oh, ow = h // 2, w_ // 2
            out = []
            for oy in range(oh):
                for ox in range(ow):
                    for ch in range(c):
                        vals = [
                            state[((2 * oy + dy) * w_ + (2 * ox + dx)) * c + ch]
                            for dy in (0, 1) for dx in (0, 1)
                        ]
                        out.append(max(vals) if ty == "max_pool2d"
                                   else sum(vals) >> 2)
            state, shape = out, [oh, ow, c]
        elif ty == "flatten":
            shape = [len(state)]
        elif ty == "save":
            saved[layer["tag"]] = list(state)
        elif ty == "add_saved":
            o = saved[layer["tag"]]
            state = [a + b for a, b in zip(state, o)]
    return state


def _rand_dense_spec(rng, dims, relu_last=False):
    layers = []
    for i in range(len(dims) - 1):
        layers.append({
            "type": "dense",
            "w": rng.integers(-31, 32, (dims[i], dims[i + 1])).tolist(),
            "b": rng.integers(-64, 65, dims[i + 1]).tolist(),
            "relu": i < len(dims) - 2 or relu_last,
            "shift": 5,
            "clip_min": -128,
            "clip_max": 127,
        })
    return {
        "name": "t", "input_bits": 8, "input_signed": True,
        "input_shape": [dims[0]], "layers": layers,
    }


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_mlp_jax_vs_pysim(seed):
    rng = np.random.default_rng(seed)
    dims = [rng.integers(2, 10) for _ in range(4)]
    spec = _rand_dense_spec(rng, dims)
    x = rng.integers(-128, 128, (3, dims[0])).astype(np.int32)
    got = np.array(forward_int(spec, x))
    for r in range(x.shape[0]):
        want = _pysim(spec, x[r])
        np.testing.assert_array_equal(got[r], want)


def test_conv_pool_jax_vs_pysim():
    rng = np.random.default_rng(1)
    spec = {
        "name": "c", "input_bits": 6, "input_signed": True,
        "input_shape": [6, 6, 2],
        "layers": [
            {"type": "conv2d",
             "w": rng.integers(-15, 16, (9 * 2, 4)).tolist(),
             "b": rng.integers(-32, 33, 4).tolist(),
             "kh": 3, "kw": 3, "relu": True, "shift": 4,
             "clip_min": -64, "clip_max": 63},
            {"type": "max_pool2d"},
            {"type": "flatten"},
            {"type": "dense",
             "w": rng.integers(-15, 16, (2 * 2 * 4, 3)).tolist(),
             "b": [0, 1, -1], "relu": False, "shift": 2,
             "clip_min": -512, "clip_max": 511},
        ],
    }
    x = rng.integers(-32, 32, (2, 72)).astype(np.int32)
    got = np.array(forward_int(spec, x))
    for r in range(2):
        np.testing.assert_array_equal(got[r], _pysim(spec, x[r]))


def test_mixer_residual_jax_vs_pysim():
    rng = np.random.default_rng(2)
    P, F = 4, 3
    def q(d_in, d_out):
        return {
            "w": rng.integers(-15, 16, (d_in, d_out)).tolist(),
            "b": rng.integers(-16, 17, d_out).tolist(),
            "relu": True, "shift": 4, "clip_min": -64, "clip_max": 63,
        }
    spec = {
        "name": "m", "input_bits": 6, "input_signed": True,
        "input_shape": [P, F],
        "layers": [
            {"type": "save", "tag": "s"},
            {"type": "einsum_dense", "axis": "feature", **q(F, F)},
            {"type": "einsum_dense", "axis": "particle", **q(P, P)},
            {"type": "add_saved", "tag": "s"},
            {"type": "flatten"},
            {"type": "dense", **q(P * F, 2)},
        ],
    }
    x = rng.integers(-32, 32, (3, P * F)).astype(np.int32)
    got = np.array(forward_int(spec, x))
    for r in range(3):
        np.testing.assert_array_equal(got[r], _pysim(spec, x[r]))


def test_hlo_text_lowering():
    rng = np.random.default_rng(3)
    spec = _rand_dense_spec(rng, [4, 6, 3])
    hlo = lower_hlo_text(spec)
    assert "HloModule" in hlo
    assert "s32" in hlo  # integer computation throughout
